//! The fault-injection subsystem end to end: deterministic link drops
//! with exactly-once retransmission, rank failure completing TAMPI
//! waits with `Err(RankFailed)` under both delivery modes, straggler
//! detection re-rooting the hierarchical trees, and shrink-then-continue
//! staying bit-identical across 1/2/4 clock lanes and converging to a
//! fault-free reference at the survivor count.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use tampi_repro::apps::recovery::{
    run_gs_shrink, run_ifs_shrink, GsShrinkParams, IfsShrinkParams, ShrinkParams,
};
use tampi_repro::rmpi::{
    commutative, ClusterConfig, DeliveryMode, FaultsConfig, ReqError, RunStats, ThreadLevel,
    TopologyMode, Universe,
};
use tampi_repro::sim::ms;
use tampi_repro::tampi;

// ------------------------------------------------------------------
// Link drops: retransmit-after-timeout through the Ports law.
// ------------------------------------------------------------------

const DROP_MSGS: i32 = 48;

fn drop_run(prob_ppm: u32) -> RunStats {
    let mut cfg = ClusterConfig::new(1, 2, 0);
    cfg.deadline = Some(ms(60_000));
    if prob_ppm > 0 {
        cfg.faults = Some(FaultsConfig::new(11).with_drop(prob_ppm));
    }
    Universe::run(cfg, move |ctx| {
        if ctx.rank == 0 {
            for i in 0..DROP_MSGS {
                let r = ctx.comm.isend(&[i], 1, i);
                r.wait(&ctx.clock);
                r.result().expect("a dropped message retransmits, it never fails");
            }
        } else {
            for i in 0..DROP_MSGS {
                let mut b = [-1i32];
                let r = ctx.comm.irecv(&mut b, 0, i);
                r.wait(&ctx.clock);
                r.result().expect("recv");
                assert_eq!(b[0], i, "payload delivered exactly once, uncorrupted");
            }
        }
    })
    .expect("drop run")
}

#[test]
fn drop_retransmits_exactly_once() {
    let clean = drop_run(0);
    assert!(clean.faults.is_none(), "no injection, no fault stats");

    let dropped = drop_run(500_000);
    let f = dropped.faults.expect("fault stats");
    assert!(f.drops > 0, "a 50% rate must hit some of {DROP_MSGS} messages");
    assert!(
        (f.drops as i64) < DROP_MSGS as i64,
        "the FNV coin must not drop everything"
    );
    // Exactly-once by construction: one delayed re-booking per drop,
    // and every payload above arrived intact.
    assert_eq!(f.drops, f.retransmits);
    assert_eq!(f.failed_reqs, 0, "drops delay, they do not fail requests");
    assert!(
        dropped.vtime_ns > clean.vtime_ns,
        "retransmission latency must be visible in virtual time"
    );

    // Seed replay: the coin is a pure hash of (seed, src, dst, tag, seq).
    let replay = drop_run(500_000);
    assert_eq!(replay.vtime_ns, dropped.vtime_ns);
    assert_eq!(replay.faults.expect("fault stats").drops, f.drops);
}

// ------------------------------------------------------------------
// Rank failure: TAMPI waits unblock with the error, both pipelines.
// ------------------------------------------------------------------

#[test]
fn rank_fail_completes_tampi_wait_with_error() {
    for mode in [DeliveryMode::Direct, DeliveryMode::Sharded] {
        let mut cfg = ClusterConfig::new(1, 2, 1);
        cfg.delivery_mode = mode;
        cfg.deadline = Some(ms(60_000));
        cfg.faults = Some(FaultsConfig::new(0).with_rank_fail(1, 10_000));
        let errs = Arc::new(AtomicU64::new(0));
        let e2 = Arc::clone(&errs);
        let stats = Universe::run(cfg, move |ctx| {
            if ctx.rank == 1 {
                // The victim idles past its death instant and exits.
                ctx.clock.work(20_000);
                return;
            }
            let rt = ctx.rt.as_ref().unwrap();
            let t = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
            let t1 = t.clone();
            let errs = Arc::clone(&e2);
            rt.task().label("doomed-recv").spawn(move || {
                let mut b = [0u8; 8];
                let r = t1.comm().irecv(&mut b, 1, 5);
                // The task parks on the request; it can only run past
                // this line if the failed completion fired on_complete.
                match t1.wait_result(&r) {
                    Err(ReqError::RankFailed { rank: 1 }) => {
                        errs.fetch_add(1, Ordering::Relaxed);
                    }
                    other => panic!("expected RankFailed {{ rank: 1 }}, got {other:?}"),
                }
            });
        })
        .expect("rank-fail run");
        assert_eq!(errs.load(Ordering::Relaxed), 1, "delivery mode {mode:?}");
        assert!(
            stats.faults.expect("fault stats").failed_reqs >= 1,
            "delivery mode {mode:?}"
        );
    }
}

// ------------------------------------------------------------------
// Straggler: entry-skew agreement re-roots the hierarchical trees.
// ------------------------------------------------------------------

/// 2 nodes x 4 ranks, world rank 4 (node 1's static representative)
/// carries a 50 us ingress penalty. Warmup is a direct token from rank
/// 0 so each rank's skew carries only its own ingress cost; the
/// adaptive arm then agrees on an avoid mask and re-roots.
fn straggler_coll_run(adaptive: bool) -> (RunStats, u64) {
    let mut cfg = ClusterConfig::new(2, 4, 0).with_topology(TopologyMode::Hierarchical);
    cfg.deadline = Some(ms(60_000));
    cfg.faults = Some(FaultsConfig::new(7).with_straggler(4, 50_000, 1));
    let mask_out = Arc::new(AtomicU64::new(0));
    let mask_c = Arc::clone(&mask_out);
    let stats = Universe::run(cfg, move |ctx| {
        let tok = [0u8; 16];
        if ctx.rank == 0 {
            let reqs: Vec<_> = (1..ctx.size).map(|d| ctx.comm.isend(&tok, d, 9)).collect();
            for r in &reqs {
                r.wait(&ctx.clock);
            }
        } else {
            let mut rbuf = [0u8; 16];
            let r = ctx.comm.irecv(&mut rbuf, 0, 9);
            r.wait(&ctx.clock);
        }
        if adaptive {
            let m = ctx.comm.detect_stragglers(20_000);
            if ctx.rank == 0 {
                mask_c.store(m, Ordering::Relaxed);
            }
        }
        let mut buf = vec![0u8; 4 * 1024];
        let mut acc = [0u64; 1];
        for _ in 0..6 {
            ctx.comm.bcast(&mut buf, 0);
            acc[0] = ctx.rank as u64;
            let max = commutative(|a: &mut [u64], b: &[u64]| a[0] = a[0].max(b[0]));
            ctx.comm.allreduce_op(&mut acc, max);
            assert_eq!(acc[0], 7, "allreduce must still see every rank");
        }
    })
    .expect("straggler run");
    let mask = mask_out.load(Ordering::Relaxed);
    (stats, mask)
}

#[test]
fn straggler_detection_reroots_and_beats_static_plans() {
    let (static_stats, _) = straggler_coll_run(false);
    let (adaptive_stats, mask) = straggler_coll_run(true);
    assert_eq!(
        mask,
        1 << 4,
        "the agreement must name exactly the injected straggler"
    );
    assert_eq!(
        adaptive_stats.faults.expect("fault stats").agreed_avoid_mask,
        1 << 4,
        "the agreed mask must be recorded as the control-plane decision"
    );
    assert!(
        adaptive_stats.vtime_ns < static_stats.vtime_ns,
        "re-rooted trees must not be slower under the straggler \
         (adaptive {} ns, static {} ns)",
        adaptive_stats.vtime_ns,
        static_stats.vtime_ns
    );
}

// ------------------------------------------------------------------
// Shrink and continue: lane-count invariance and convergence.
// ------------------------------------------------------------------

#[test]
fn shrink_then_allreduce_bit_identical_across_lanes() {
    let run = |shards: usize| {
        let mut cfg = ClusterConfig::new(4, 1, 0);
        cfg.clock_shards = shards;
        cfg.deadline = Some(ms(60_000));
        cfg.faults = Some(FaultsConfig::new(3).with_rank_fail(2, 5_000));
        let sum = Arc::new(AtomicU64::new(0));
        let s2 = Arc::clone(&sum);
        let stats = Universe::run(cfg, move |ctx| {
            // Everyone past the death instant: the oracle's verdict is
            // unanimous without any message exchange.
            ctx.clock.work(6_000);
            if ctx.rank == 2 {
                return;
            }
            let small = ctx.comm.comm_shrink();
            assert_eq!(small.size(), 3);
            let mut v = [(ctx.rank + 1) as u64];
            small.allreduce_op(&mut v, commutative(|a: &mut [u64], b: &[u64]| a[0] += b[0]));
            // Survivors are world ranks 0, 1, 3 -> 1 + 2 + 4.
            assert_eq!(v[0], 7, "allreduce on the shrunk communicator");
            if small.rank() == 0 {
                s2.store(v[0], Ordering::Relaxed);
            }
        })
        .expect("shrink allreduce");
        (stats.vtime_ns, sum.load(Ordering::Relaxed))
    };
    let one = run(1);
    let two = run(2);
    let four = run(4);
    assert_eq!(one.1, 7);
    assert_eq!(one, two, "1 vs 2 clock lanes");
    assert_eq!(one, four, "1 vs 4 clock lanes");
}

#[test]
fn gs_shrink_converges_and_is_lane_invariant() {
    let outcome = |shards: usize| {
        let mut b = ShrinkParams::new(4, 1, 2, 6);
        b.clock_shards = shards;
        b.deadline = Some(ms(60_000));
        b.faults = Some(FaultsConfig::new(42).with_rank_fail(1, 20_000));
        run_gs_shrink(&GsShrinkParams::new(b, 24, 64)).expect("gs shrink")
    };
    let one = outcome(1);
    let two = outcome(2);
    let four = outcome(4);
    assert_eq!(one.survivors, 3, "one of four ranks died");
    for other in [&two, &four] {
        assert_eq!(one.vtime_ns, other.vtime_ns);
        assert_eq!(one.checksum.to_bits(), other.checksum.to_bits());
    }

    // Convergence: the recovered phase restarts from the initial
    // condition, so it is bit-identical to a clean 3-rank run.
    let mut rb = ShrinkParams::new(3, 1, 0, 6);
    rb.deadline = Some(ms(60_000));
    let reference = run_gs_shrink(&GsShrinkParams::new(rb, 24, 64)).expect("reference");
    assert!(one.checksum.is_finite() && one.checksum != 0.0);
    assert_eq!(one.checksum.to_bits(), reference.checksum.to_bits());
}

#[test]
fn ifsker_shrink_converges_and_is_lane_invariant() {
    let outcome = |shards: usize| {
        let mut b = ShrinkParams::new(4, 1, 1, 3);
        b.clock_shards = shards;
        b.deadline = Some(ms(60_000));
        b.faults = Some(FaultsConfig::new(42).with_rank_fail(1, 20_000));
        run_ifs_shrink(&IfsShrinkParams::new(b, 144, 2)).expect("ifs shrink")
    };
    let one = outcome(1);
    let two = outcome(2);
    let four = outcome(4);
    assert_eq!(one.survivors, 3);
    for other in [&two, &four] {
        assert_eq!(one.vtime_ns, other.vtime_ns);
        assert_eq!(one.checksum.to_bits(), other.checksum.to_bits());
    }

    let mut rb = ShrinkParams::new(3, 1, 0, 3);
    rb.deadline = Some(ms(60_000));
    let reference = run_ifs_shrink(&IfsShrinkParams::new(rb, 144, 2)).expect("reference");
    assert!(one.checksum.is_finite() && one.checksum != 0.0);
    assert_eq!(one.checksum.to_bits(), reference.checksum.to_bits());
}
