//! Unit tests for the trace renderers on fixed synthetic records:
//! `render_gantt` (lane-state precedence, bucket boundaries, degenerate
//! snapshots) and `GraphRecorder::to_dot` (determinism, highlighting,
//! duplicate-edge fusion). The end-to-end tracing tests live in
//! `trace_graph.rs`; these pin the rendering rules themselves.

use tampi_repro::trace::{busy_fraction, render_gantt, EventKind, GraphRecorder, Record};

fn rec(t: u64, rank: u32, worker: u32, kind: EventKind, task_id: u64) -> Record {
    Record { t, rank, worker, kind, label: String::new(), task_id }
}

#[test]
fn empty_trace_renders_labeled_chart() {
    assert_eq!(render_gantt(&[], 100), "(empty trace)\n");
    assert!(busy_fraction(&[]).is_empty());
}

#[test]
fn single_instant_trace_is_labeled_degenerate() {
    // Every record at one instant: there is no span to bucket. The old
    // renderer smeared a fake 1 ns span across all columns.
    let recs = vec![
        rec(500, 0, 0, EventKind::TaskStart, 1),
        rec(500, 0, 0, EventKind::TaskEnd, 1),
        rec(500, 1, 0, EventKind::TaskStart, 2),
    ];
    let chart = render_gantt(&recs, 100);
    assert!(
        chart.starts_with("(degenerate trace: 3 records"),
        "unexpected chart: {chart}"
    );
    assert!(chart.contains("t = 500 ns"), "{chart}");
    // busy_fraction degrades to all-zero fractions, no panic.
    for (&rank, &f) in &busy_fraction(&recs) {
        assert_eq!(f, 0.0, "rank {rank}");
    }
}

#[test]
fn lane_state_precedence_picks_dominant_state_per_bucket() {
    // One lane over exactly 1000 ns, 10 buckets of 100 ns:
    //   Task 0..540, MPI 540..600, Task 600..900, Paused 900..960,
    //   Task 960..1000.
    // Bucket 5 (500..600): Task holds 40 ns, MPI 60 ns -> 'M'.
    // Bucket 9 (900..1000): Paused holds 60 ns, Task 40 ns -> 'b'.
    let recs = vec![
        rec(0, 0, 0, EventKind::TaskStart, 1),
        rec(540, 0, 0, EventKind::MpiStart, 1),
        rec(600, 0, 0, EventKind::MpiEnd, 1),
        rec(900, 0, 0, EventKind::TaskBlock, 1),
        rec(960, 0, 0, EventKind::TaskUnblock, 1),
        rec(1000, 0, 0, EventKind::TaskEnd, 1),
    ];
    let chart = render_gantt(&recs, 10);
    assert!(chart.contains("r00w00 |#####M###b|"), "unexpected chart:\n{chart}");
}

#[test]
fn bucket_boundaries_do_not_bleed() {
    // Task ends exactly on the bucket-5 boundary (t=500 of 0..1000):
    // bucket 5 must stay idle. The trailing Phase record pins the span
    // end without contributing occupancy.
    let recs = vec![
        rec(0, 0, 0, EventKind::TaskStart, 1),
        rec(500, 0, 0, EventKind::TaskEnd, 1),
        rec(1000, 0, 0, EventKind::Phase, 0),
    ];
    let chart = render_gantt(&recs, 10);
    assert!(chart.contains("r00w00 |#####.....|"), "unexpected chart:\n{chart}");
}

#[test]
fn annotation_records_do_not_create_lanes() {
    // Annotation kinds may be stamped from non-worker threads (sentinel
    // worker id); they must not fabricate a lane or a rank entry.
    let recs = vec![
        rec(0, 0, 0, EventKind::TaskStart, 1),
        rec(1000, 0, 0, EventKind::TaskEnd, 1),
        rec(500, 3, u32::MAX, EventKind::CompletionDelivered, 7),
    ];
    let chart = render_gantt(&recs, 10);
    assert_eq!(
        chart.lines().filter(|l| l.starts_with('r')).count(),
        1,
        "annotation created a lane:\n{chart}"
    );
    let busy = busy_fraction(&recs);
    assert_eq!(busy.len(), 1);
    assert!((busy[&0] - 1.0).abs() < 1e-9, "lane is fully busy: {busy:?}");
}

#[test]
fn gantt_output_is_deterministic() {
    let recs = vec![
        rec(0, 1, 0, EventKind::TaskStart, 1),
        rec(300, 1, 0, EventKind::TaskBlock, 1),
        rec(700, 1, 0, EventKind::TaskUnblock, 1),
        rec(1000, 1, 0, EventKind::TaskEnd, 1),
        rec(0, 0, 1, EventKind::TaskStart, 2),
        rec(1000, 0, 1, EventKind::TaskEnd, 2),
    ];
    let a = render_gantt(&recs, 20);
    let b = render_gantt(&recs, 20);
    assert_eq!(a, b);
    // Lanes are sorted by (rank, worker).
    let lanes: Vec<&str> = a.lines().filter(|l| l.starts_with('r')).collect();
    assert!(lanes[0].starts_with("r00w01"), "{a}");
    assert!(lanes[1].starts_with("r01w00"), "{a}");
}

#[test]
fn dot_highlights_matching_edges_and_fuses_duplicates() {
    let g = GraphRecorder::new();
    g.add_node(1, "send(0,0)", 0);
    g.add_node(2, "recv(h0)", 0);
    g.add_node(3, "gs[0](0,0)", 1);
    g.add_edge(1, 2, "r0sentinel");
    g.add_edge(1, 2, "r0sentinel"); // duplicate: must be fused
    g.add_edge(2, 3, "r1b0");
    let dot = g.to_dot("sentinel");
    assert_eq!(
        dot.matches("t1 -> t2").count(),
        1,
        "duplicate edges must fuse:\n{dot}"
    );
    assert!(dot.contains("t1 -> t2 [color=red,penwidth=2];"), "{dot}");
    assert!(dot.contains("t2 -> t3;"), "non-matching edge stays plain:\n{dot}");
    assert!(dot.contains("cluster_rank0") && dot.contains("cluster_rank1"));
    // No highlight pattern -> no red edges.
    assert!(!g.to_dot("").contains("color=red"));
}

#[test]
fn dot_output_is_deterministic() {
    let mk = || {
        let g = GraphRecorder::new();
        for id in 0..6u64 {
            g.add_node(id, &format!("t{id}"), (id % 2) as u32);
        }
        for id in 0..5u64 {
            g.add_edge(id, id + 1, "obj");
        }
        g.to_dot("obj")
    };
    assert_eq!(mk(), mk());
}
