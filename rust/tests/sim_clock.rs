//! Virtual-clock semantics: quiescence advancement, overlap, ordering.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use tampi_repro::sim::{ms, Clock};

#[test]
fn sleepers_overlap_in_virtual_time() {
    let (clock, h) = Clock::start();
    clock.set_panic_on_deadlock(false);
    let mut joins = Vec::new();
    let finish = Arc::new(AtomicU64::new(0));
    for _ in 0..4 {
        let c = clock.clone();
        let f = finish.clone();
        clock.register_thread();
        joins.push(std::thread::spawn(move || {
            c.sleep(ms(10));
            f.fetch_max(c.now(), Ordering::AcqRel);
            c.deregister_thread();
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    // 4 concurrent sleeps of 10 ms take 10 ms, not 40.
    assert_eq!(finish.load(Ordering::Acquire), ms(10));
    clock.stop();
    h.join().unwrap();
}

#[test]
fn sequential_work_accumulates() {
    let (clock, h) = Clock::start();
    clock.set_panic_on_deadlock(false);
    clock.register_thread();
    let c = clock.clone();
    let j = std::thread::spawn(move || {
        c.work(ms(3));
        c.work(ms(4));
        let t = c.now();
        c.deregister_thread();
        t
    });
    assert_eq!(j.join().unwrap(), ms(7));
    clock.stop();
    h.join().unwrap();
}

#[test]
fn call_at_fires_in_order() {
    let (clock, h) = Clock::start();
    clock.set_panic_on_deadlock(false);
    // Pin the clock during setup: events must not fire while scheduling.
    let hold = clock.hold();
    let log = Arc::new(std::sync::Mutex::new(Vec::new()));
    for (t, v) in [(ms(5), 5u64), (ms(2), 2), (ms(9), 9)] {
        let l = log.clone();
        clock.call_at(t, move || l.lock().unwrap().push(v));
    }
    clock.register_thread();
    drop(hold);
    let c = clock.clone();
    let j = std::thread::spawn(move || {
        c.sleep(ms(20));
        c.deregister_thread();
    });
    j.join().unwrap();
    assert_eq!(*log.lock().unwrap(), vec![2, 5, 9]);
    clock.stop();
    h.join().unwrap();
}

#[test]
fn wake_before_wait_is_consumed() {
    let (clock, h) = Clock::start();
    clock.set_panic_on_deadlock(false);
    let tok = tampi_repro::sim::Token::new();
    clock.wake(&tok);
    clock.register_thread();
    let c = clock.clone();
    let t2 = tok.clone();
    let j = std::thread::spawn(move || {
        c.passive_wait(&t2); // returns immediately
        c.work(ms(1));
        c.deregister_thread();
    });
    j.join().unwrap();
    assert_eq!(clock.now(), ms(1));
    clock.stop();
    h.join().unwrap();
}

#[test]
fn deadlock_detected_when_no_events() {
    let (clock, h) = Clock::start();
    clock.set_panic_on_deadlock(false);
    clock.register_thread();
    let c = clock.clone();
    let _j = std::thread::spawn(move || {
        // Park on a token nobody will ever wake.
        let tok = tampi_repro::sim::Token::new();
        c.passive_wait(&tok);
        c.deregister_thread();
    });
    // Real-time poll until the clock flags the deadlock.
    for _ in 0..2000 {
        if clock.deadlocked() {
            clock.stop();
            h.join().unwrap();
            return; // leak the parked thread (intentional)
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    panic!("deadlock not detected");
}

#[test]
fn waitqueue_fifo_wakeup() {
    use tampi_repro::sim::WaitQueue;
    let (clock, h) = Clock::start();
    clock.set_panic_on_deadlock(false);
    let q = Arc::new(WaitQueue::new());
    let order = Arc::new(std::sync::Mutex::new(Vec::new()));
    let mut joins = Vec::new();
    for i in 0..3u32 {
        let c = clock.clone();
        let q2 = q.clone();
        let o = order.clone();
        clock.register_thread();
        joins.push(std::thread::spawn(move || {
            // Stagger arrival so enqueue order is deterministic.
            c.sleep(ms(1 + i as u64));
            let tok = q2.enqueue();
            c.passive_wait(&tok);
            o.lock().unwrap().push(i);
            c.deregister_thread();
        }));
    }
    // Waker: after everyone is parked, release one per ms.
    let c = clock.clone();
    let q2 = q.clone();
    clock.register_thread();
    joins.push(std::thread::spawn(move || {
        c.sleep(ms(10));
        for _ in 0..3 {
            q2.notify_one(&c);
            c.sleep(ms(1));
        }
        c.deregister_thread();
    }));
    for j in joins {
        j.join().unwrap();
    }
    assert_eq!(*order.lock().unwrap(), vec![0, 1, 2]);
    clock.stop();
    h.join().unwrap();
}
