//! Schedule-driven collective engine: non-blocking collectives as
//! first-class requests (rmpi::coll_schedule / collectives), TAMPI
//! collective interception, event-decrement coalescing, and the
//! blocking-vs-non-blocking application acceptance criteria.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use tampi_repro::apps::gauss_seidel::{self, GsParams, GsVersion};
use tampi_repro::apps::ifsker::{self, IfsParams, IfsVersion};
use tampi_repro::bench;
use tampi_repro::nanos::{self, Mode};
use tampi_repro::progress::DeliveryMode;
use tampi_repro::rmpi::{ClusterConfig, Request, ThreadLevel, Universe};
use tampi_repro::sim::ms;
use tampi_repro::tampi;
use tampi_repro::trace::{EventKind, Tracer};

/// Per-rank schedule shapes: round counts of each algorithm on 8 ranks.
#[test]
fn schedule_round_counts_per_algorithm() {
    let n = 8usize;
    Universe::run(ClusterConfig::new(n, 1, 0), move |ctx| {
        let r = ctx.rank;

        // Dissemination barrier: rounds 1, 2, 4 -> 3 rounds everywhere.
        let cr = ctx.comm.ibarrier();
        assert_eq!(cr.rounds_total(), 3, "rank {r} barrier rounds");
        assert_eq!(cr.kind(), "barrier");
        cr.wait();
        assert_eq!(cr.rounds_advanced(), cr.rounds_total());

        // Binomial bcast: the root only forwards (1 round); everyone
        // else receives then forwards (2 rounds).
        let mut b = [0u64; 2];
        if r == 0 {
            b = [7, 9];
        }
        let cr = ctx.comm.ibcast(&mut b, 0);
        let want = if r == 0 { 1 } else { 2 };
        assert_eq!(cr.rounds_total(), want, "rank {r} bcast rounds");
        cr.wait();
        assert_eq!(b, [7, 9], "rank {r} bcast payload");

        // Binomial reduce: leaves combine+send (1 round); interior
        // ranks and the root first post child receives (2 rounds).
        let mut v = [r as u64];
        let cr = ctx.comm.ireduce(&mut v, 0, |a, b| a[0] += b[0]);
        let vr = r; // root 0 => virtual rank == rank
        let has_children = vr % 2 == 0 && n > 1;
        let want = if has_children { 2 } else { 1 };
        assert_eq!(cr.rounds_total(), want, "rank {r} reduce rounds");
        cr.wait();
        if r == 0 {
            assert_eq!(v[0], (0..n as u64).sum::<u64>());
        }

        // Allreduce chains reduce + bcast schedules.
        let mut w = [r as u64 + 1];
        let cr = ctx.comm.iallreduce(&mut w, |a, b| a[0] += b[0]);
        let reduce_rounds = if has_children { 2 } else { 1 };
        let bcast_rounds = if r == 0 { 1 } else { 2 };
        assert_eq!(cr.rounds_total(), reduce_rounds + bcast_rounds, "rank {r}");
        cr.wait();
        assert_eq!(w[0], (1..=n as u64).sum::<u64>());

        // Gather and alltoallv are single-round schedules.
        let mine = [r as u32];
        if r == 3 {
            let mut all = vec![0u32; n];
            let cr = ctx.comm.igather(&mine, Some(&mut all), 3);
            assert_eq!(cr.rounds_total(), 1);
            cr.wait();
            assert_eq!(all, (0..n as u32).collect::<Vec<_>>());
        } else {
            let cr = ctx.comm.igather(&mine, None, 3);
            assert_eq!(cr.rounds_total(), 1);
            cr.wait();
        }
        let send: Vec<u32> = (0..n).map(|d| (r * 100 + d) as u32).collect();
        let mut recv = vec![0u32; n];
        let cr = ctx.comm.ialltoall(&send, &mut recv);
        assert_eq!(cr.rounds_total(), 1);
        cr.wait();
        for s in 0..n {
            assert_eq!(recv[s], (s * 100 + r) as u32);
        }
    })
    .unwrap();
}

/// iallreduce must agree bit-for-bit with the blocking allreduce, across
/// Park / TaskAware wait styles and Direct / Sharded delivery.
#[test]
fn iallreduce_matches_blocking_allreduce_across_modes() {
    let n = 6usize;
    let run = |delivery: DeliveryMode, style: &'static str| -> u64 {
        let bits = Arc::new(AtomicU64::new(0));
        let b2 = bits.clone();
        let cores = if style == "taskaware" { 1 } else { 0 };
        let cfg = ClusterConfig::new(n, 1, cores).with_delivery_mode(delivery);
        Universe::run(cfg, move |ctx| {
            let seed = (ctx.rank as f64 + 0.5) * 1.25;
            let result = match style {
                "park" => {
                    let mut v = [seed];
                    ctx.comm.allreduce(&mut v, |a, b| a[0] += b[0]);
                    v[0]
                }
                "icoll" => {
                    let mut v = [seed];
                    let cr = ctx.comm.iallreduce(&mut v, |a, b| a[0] += b[0]);
                    cr.wait();
                    v[0]
                }
                _ => {
                    let rt = ctx.rt.as_ref().unwrap();
                    let tm = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
                    let out = Arc::new(Mutex::new(0.0f64));
                    let o2 = out.clone();
                    rt.task().label("coll").spawn(move || {
                        let mut v = [seed];
                        tm.allreduce(&mut v, |a, b| a[0] += b[0]);
                        *o2.lock().unwrap() = v[0];
                    });
                    rt.taskwait();
                    *out.lock().unwrap()
                }
            };
            if ctx.rank == 0 {
                b2.store(result.to_bits(), Ordering::Release);
            }
        })
        .unwrap();
        bits.load(Ordering::Acquire)
    };
    let reference = run(DeliveryMode::Sharded, "park");
    assert!(f64::from_bits(reference) > 0.0);
    for delivery in [DeliveryMode::Direct, DeliveryMode::Sharded] {
        for style in ["park", "icoll", "taskaware"] {
            assert_eq!(
                run(delivery, style),
                reference,
                "allreduce diverged under {delivery:?}/{style}"
            );
        }
    }
}

/// `Tampi::ibcast` binds the collective to the task's dependency release
/// through the external-events API: the consumer task runs only after
/// the broadcast payload really arrived, with zero pauses (Fig 4's flow
/// over a collective).
#[test]
fn ibcast_event_binding_defers_task_release() {
    let consumer_t = Arc::new(AtomicU64::new(0));
    let seen = Arc::new(AtomicU64::new(0));
    let (ct2, s2) = (consumer_t.clone(), seen.clone());
    let stats = Universe::run(ClusterConfig::new(2, 1, 1), move |ctx| {
        if ctx.rank == 0 {
            // Root delays, so the non-root's collective stays in flight
            // long after its comm task finished.
            ctx.clock.sleep(ms(5));
            let mut v = [4242u64];
            ctx.comm.bcast(&mut v, 0);
        } else {
            let rt = ctx.rt.as_ref().unwrap();
            let tm = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
            let buf: Arc<Mutex<[u64; 1]>> = Arc::new(Mutex::new([0]));
            let obj = rt.dep("bcast-buf");
            let (t1, b1) = (tm.clone(), buf.clone());
            rt.task().label("comm").dep(&obj, Mode::Out).spawn(move || {
                let mut g = b1.lock().unwrap();
                t1.ibcast(&mut *g, 0);
                // returns immediately; deps held by the external event
            });
            let (ct, s, b2) = (ct2.clone(), s2.clone(), buf.clone());
            rt.task().label("consume").dep(&obj, Mode::In).spawn(move || {
                ct.store(nanos::current_clock().now(), Ordering::Release);
                s.store(b2.lock().unwrap()[0], Ordering::Release);
            });
        }
    })
    .unwrap();
    assert_eq!(seen.load(Ordering::Acquire), 4242);
    assert!(
        consumer_t.load(Ordering::Acquire) >= ms(5),
        "consumer ran before the broadcast arrived"
    );
    assert_eq!(stats.pauses, 0, "non-blocking collective must not pause tasks");
}

/// A CollRequest composes with `Request::wait_any` alongside p2p
/// requests (first-class request acceptance).
#[test]
fn wait_any_over_mixed_p2p_and_collective_requests() {
    Universe::run(ClusterConfig::new(2, 1, 0), |ctx| {
        if ctx.rank == 0 {
            let mut b = [0u32];
            let p2p = ctx.comm.irecv(&mut b, 1, 9);
            let coll = ctx.comm.ibarrier();
            let reqs = [p2p.clone(), coll.request().clone()];
            let idx = Request::wait_any(&ctx.clock, &reqs);
            assert_eq!(idx, 0, "the early p2p message must win");
            assert_eq!(b[0], 77);
            assert!(!coll.test(), "barrier cannot be done before rank 1 enters");
            coll.wait();
            assert!(ctx.clock.now() >= ms(8), "barrier completed too early");
            assert_eq!(coll.rounds_advanced(), coll.rounds_total());
        } else {
            ctx.clock.sleep(ms(2));
            ctx.comm.send(&[77u32], 0, 9);
            ctx.clock.sleep(ms(6)); // enter the barrier late
            ctx.comm.barrier();
        }
    })
    .unwrap();
}

/// Blocking collectives are wrappers over the schedule engine: a plain
/// `barrier()` call advances engine rounds (visible as
/// `CollRoundAdvanced` trace records on every rank).
#[test]
fn blocking_collectives_drive_through_the_engine() {
    let n = 4usize;
    let tracer = Arc::new(Tracer::new());
    let mut cfg = ClusterConfig::new(n, 1, 0);
    cfg.tracer = Some(tracer.clone());
    Universe::run(cfg, |ctx| {
        ctx.comm.barrier();
    })
    .unwrap();
    let mut per_rank = vec![0u32; n];
    for rec in tracer.snapshot() {
        if let EventKind::CollRoundAdvanced { round, total, .. } = rec.kind {
            assert_eq!(total, 2, "log2(4) dissemination rounds");
            assert!((1..=total).contains(&round));
            assert_eq!(rec.label, "barrier");
            per_rank[rec.rank as usize] += 1;
        }
    }
    for (r, &count) in per_rank.iter().enumerate() {
        assert_eq!(count, 2, "rank {r} must advance every round through the engine");
    }
}

/// A shard drain coalesces same-task external-event decrements: a wave
/// fulfilling K events of ONE task applies one `dec_events(K)` under
/// Sharded delivery, K separate decrements under Direct.
#[test]
fn shard_drain_coalesces_event_decrements() {
    let k = 16usize;
    let run = |delivery: DeliveryMode| {
        let cfg = ClusterConfig::new(2, 1, 1).with_delivery_mode(delivery);
        Universe::run(cfg, move |ctx| {
            if ctx.rank == 0 {
                let rt = ctx.rt.as_ref().unwrap();
                let tm = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
                // Kept alive by this rank main until taskwait returns
                // (full completion releases the task's events first).
                let bufs: Arc<Mutex<Vec<[u32; 1]>>> =
                    Arc::new(Mutex::new(vec![[0u32]; k]));
                let b1 = bufs.clone();
                let tm2 = tm.clone();
                rt.task().label("iwaitall").spawn(move || {
                    let mut g = b1.lock().unwrap();
                    let mut reqs = Vec::new();
                    for (i, b) in g.iter_mut().enumerate() {
                        reqs.push(tm2.comm().irecv(b, 1, i as i32));
                    }
                    drop(g);
                    tm2.iwaitall(&reqs); // K events on one task
                });
                rt.taskwait();
                assert!(bufs.lock().unwrap().iter().all(|b| b[0] == 1));
            } else {
                ctx.clock.sleep(ms(5));
                // One virtual instant: eager isends back-to-back.
                let reqs: Vec<_> =
                    (0..k).map(|i| ctx.comm.isend(&[1u32], 0, i as i32)).collect();
                assert!(reqs.iter().all(|r| r.test()));
            }
        })
        .unwrap()
    };
    let direct = run(DeliveryMode::Direct);
    let sharded = run(DeliveryMode::Sharded);
    assert_eq!(
        direct.event_dec_ops, k as u64,
        "Direct: one decrement per continuation"
    );
    assert_eq!(
        sharded.event_dec_ops, 1,
        "Sharded: the wave must coalesce into one dec_events(K)"
    );
    assert_eq!(direct.vtime_ns, sharded.vtime_ns, "coalescing is time-neutral");
}

/// Lock-free MPSC shard deposit: counter parity with the mutex-era
/// behaviour — same deliveries, same single-batch wave, same virtual
/// time as Direct delivery.
#[test]
fn mpsc_deposit_counter_parity() {
    let n = 32usize;
    let d = bench::completion_wave(n, DeliveryMode::Direct);
    let s = bench::completion_wave(n, DeliveryMode::Sharded);
    assert_eq!(s.deliveries, n as u64, "every continuation must be delivered");
    assert_eq!(s.max_batch, n as u64, "the wave lands as one batch");
    assert_eq!(
        s.delivery_batches, 1,
        "one empty->non-empty transition schedules exactly one drain"
    );
    assert_eq!(d.deliveries, 0, "Direct bypasses the shards");
    assert_eq!(d.vtime_ns, s.vtime_ns, "deposit structure must not change time");
}

/// Rank-count sweep: resume-lock traffic is O(N) under Direct and
/// O(shards) under Sharded for the same total wave (fig15 extension).
#[test]
fn wave_lock_ops_cross_over_with_rank_count() {
    let total = 16usize;
    for receivers in [2usize, 4] {
        let per = total / receivers;
        let d = bench::completion_wave_ranks(receivers, per, DeliveryMode::Direct);
        let s = bench::completion_wave_ranks(receivers, per, DeliveryMode::Sharded);
        assert!(
            d.resume_lock_ops >= total as u64,
            "Direct: O(N) lock ops, got {} for N={total}",
            d.resume_lock_ops
        );
        assert!(
            s.resume_lock_ops <= 2 * receivers as u64,
            "Sharded: O(shards) lock ops, got {} for {receivers} shards",
            s.resume_lock_ops
        );
        assert_eq!(d.vtime_ns, s.vtime_ns);
    }
}

/// Acceptance criterion: Gauss-Seidel with residual monitoring produces
/// bit-identical grid checksums AND residuals across
/// {blocking, non-blocking} x {Direct, Sharded}.
#[test]
fn gs_checksums_bitidentical_across_residual_style_and_delivery() {
    let run = |nonblocking: bool, delivery: DeliveryMode| {
        let mut p = GsParams::new(128, 128, 32, 4, 2, 2, GsVersion::InteropNonBlk);
        p.residual_every = 2;
        p.residual_nonblocking = nonblocking;
        p.delivery_mode = delivery;
        p.deadline = Some(ms(60_000));
        gauss_seidel::run(&p).unwrap()
    };
    let base = run(false, DeliveryMode::Direct);
    assert!(base.checksum > 0.0, "heat must flow");
    assert!(base.residual > 0.0, "residual must be recorded");
    for nonblocking in [false, true] {
        for delivery in [DeliveryMode::Direct, DeliveryMode::Sharded] {
            let out = run(nonblocking, delivery);
            assert_eq!(
                out.checksum.to_bits(),
                base.checksum.to_bits(),
                "gs checksum diverged (nonblocking={nonblocking}, {delivery:?})"
            );
            assert_eq!(
                out.residual.to_bits(),
                base.residual.to_bits(),
                "gs residual diverged (nonblocking={nonblocking}, {delivery:?})"
            );
        }
    }
}

/// Same acceptance criterion for IFSKer.
#[test]
fn ifsker_checksums_bitidentical_across_residual_style_and_delivery() {
    let run = |nonblocking: bool, delivery: DeliveryMode| {
        // 2 nodes x 2 ranks/node = 4 ranks; chunk 16 divisible by 4.
        let mut p = IfsParams::new(256, 2, 4, 2, 2, IfsVersion::InteropNonBlk);
        p.residual_every = 2;
        p.residual_nonblocking = nonblocking;
        p.delivery_mode = delivery;
        p.deadline = Some(ms(60_000));
        ifsker::run(&p).unwrap()
    };
    let base = run(false, DeliveryMode::Direct);
    assert!(base.checksum > 0.0);
    assert!(base.residual > 0.0);
    for nonblocking in [false, true] {
        for delivery in [DeliveryMode::Direct, DeliveryMode::Sharded] {
            let out = run(nonblocking, delivery);
            assert_eq!(
                out.checksum.to_bits(),
                base.checksum.to_bits(),
                "ifsker checksum diverged (nonblocking={nonblocking}, {delivery:?})"
            );
            assert_eq!(
                out.residual.to_bits(),
                base.residual.to_bits(),
                "ifsker residual diverged (nonblocking={nonblocking}, {delivery:?})"
            );
        }
    }
}

/// Non-blocking residual monitoring must not be slower than blocking
/// residual monitoring (the fig16 overlap claim, app-level).
#[test]
fn nonblocking_residual_overlap_is_not_slower() {
    let run = |nonblocking: bool| {
        let mut p = GsParams::new(256, 256, 64, 8, 2, 2, GsVersion::InteropNonBlk);
        p.compute = tampi_repro::apps::Compute::Model;
        p.residual_every = 1;
        p.residual_nonblocking = nonblocking;
        p.deadline = Some(ms(600_000));
        gauss_seidel::run(&p).unwrap()
    };
    let blk = run(false);
    let nblk = run(true);
    assert_eq!(blk.residual.to_bits(), nblk.residual.to_bits());
    assert!(
        nblk.vtime_ns <= blk.vtime_ns,
        "fire-and-forget iallreduce ({} ns) must not be slower than the \
         blocking residual ({} ns)",
        nblk.vtime_ns,
        blk.vtime_ns
    );
}
