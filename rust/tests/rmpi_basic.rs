//! rmpi substrate: p2p semantics, timing, collectives, Section 5 deadlock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use tampi_repro::rmpi::{
    ClusterConfig, NetworkModel, Request, Universe, ANY_SOURCE, ANY_TAG,
};
use tampi_repro::sim::{ms, us};

fn two_ranks() -> ClusterConfig {
    ClusterConfig::new(2, 1, 0) // 2 nodes x 1 rank, no task runtime
}

#[test]
fn ping_pong_transfers_data_and_time() {
    let got = Arc::new(Mutex::new((0u64, 0i32, 0usize)));
    let g2 = got.clone();
    let stats = Universe::run(two_ranks(), move |ctx| {
        if ctx.rank == 0 {
            let data = [42.5f32, -1.0, 7.25];
            ctx.comm.send(&data, 1, 7);
        } else {
            let mut buf = [0f32; 3];
            let st = ctx.comm.recv(&mut buf, 0, 7);
            assert_eq!(buf, [42.5, -1.0, 7.25]);
            *g2.lock().unwrap() = (ctx.clock.now(), st.source, st.bytes);
        }
    })
    .unwrap();
    let (t, src, bytes) = *got.lock().unwrap();
    assert_eq!(src, 0);
    assert_eq!(bytes, 12);
    // Inter-node latency is 1.5us; must be reflected in virtual time.
    assert!(t >= 1_500, "recv completed at {t} ns, before the wire latency");
    assert!(stats.vtime_ns >= 1_500);
}

#[test]
fn intra_node_is_faster_than_inter_node() {
    let t_intra = Arc::new(AtomicU64::new(0));
    let t2 = t_intra.clone();
    Universe::run(ClusterConfig::new(1, 2, 0), move |ctx| {
        if ctx.rank == 0 {
            ctx.comm.send(&[1u8; 64], 1, 0);
        } else {
            let mut b = [0u8; 64];
            ctx.comm.recv(&mut b, 0, 0);
            t2.store(ctx.clock.now(), Ordering::Release);
        }
    })
    .unwrap();
    let t_inter = Arc::new(AtomicU64::new(0));
    let t2 = t_inter.clone();
    Universe::run(ClusterConfig::new(2, 1, 0), move |ctx| {
        if ctx.rank == 0 {
            ctx.comm.send(&[1u8; 64], 1, 0);
        } else {
            let mut b = [0u8; 64];
            ctx.comm.recv(&mut b, 0, 0);
            t2.store(ctx.clock.now(), Ordering::Release);
        }
    })
    .unwrap();
    assert!(
        t_intra.load(Ordering::Acquire) < t_inter.load(Ordering::Acquire),
        "shared-memory hop must beat the fabric"
    );
}

#[test]
fn message_order_preserved_same_pair_and_tag() {
    Universe::run(two_ranks(), |ctx| {
        if ctx.rank == 0 {
            for i in 0..10i32 {
                ctx.comm.send(&[i], 1, 3);
            }
        } else {
            for i in 0..10i32 {
                let mut b = [0i32];
                ctx.comm.recv(&mut b, 0, 3);
                assert_eq!(b[0], i, "non-overtaking violated");
            }
        }
    })
    .unwrap();
}

#[test]
fn wildcard_source_and_tag() {
    Universe::run(ClusterConfig::new(3, 1, 0), |ctx| {
        if ctx.rank == 2 {
            let mut seen = [false; 2];
            for _ in 0..2 {
                let mut b = [0i32];
                let st = ctx.comm.recv(&mut b, ANY_SOURCE, ANY_TAG);
                assert_eq!(b[0], st.source * 100 + st.tag);
                seen[st.source as usize] = true;
            }
            assert!(seen[0] && seen[1]);
        } else {
            let tag = ctx.rank as i32 + 5;
            ctx.comm.send(&[(ctx.rank as i32) * 100 + tag], 2, tag);
        }
    })
    .unwrap();
}

#[test]
fn ssend_completes_only_after_match() {
    let sender_done = Arc::new(AtomicU64::new(0));
    let s2 = sender_done.clone();
    Universe::run(two_ranks(), move |ctx| {
        if ctx.rank == 0 {
            ctx.comm.ssend(&[9u8], 1, 0);
            s2.store(ctx.clock.now(), Ordering::Release);
        } else {
            ctx.clock.sleep(ms(5)); // delay the matching recv
            let mut b = [0u8];
            ctx.comm.recv(&mut b, 0, 0);
        }
    })
    .unwrap();
    assert!(
        sender_done.load(Ordering::Acquire) >= ms(5),
        "ssend returned before the receive was posted"
    );
}

#[test]
fn eager_send_completes_immediately_but_rendezvous_waits() {
    let eager_done = Arc::new(AtomicU64::new(u64::MAX));
    let rndv_done = Arc::new(AtomicU64::new(0));
    let (e2, r2) = (eager_done.clone(), rndv_done.clone());
    Universe::run(two_ranks(), move |ctx| {
        if ctx.rank == 0 {
            ctx.comm.send(&[1u8; 16], 1, 0); // eager
            e2.store(ctx.clock.now(), Ordering::Release);
            let big = vec![2u8; 1 << 20]; // > eager threshold
            ctx.comm.send(&big, 1, 1);
            r2.store(ctx.clock.now(), Ordering::Release);
        } else {
            ctx.clock.sleep(ms(3));
            let mut small = [0u8; 16];
            ctx.comm.recv(&mut small, 0, 0);
            let mut big = vec![0u8; 1 << 20];
            ctx.comm.recv(&mut big, 0, 1);
            assert!(big.iter().all(|&b| b == 2));
        }
    })
    .unwrap();
    // Eager sends buffer and return after only the per-call CPU cost.
    assert!(
        eager_done.load(Ordering::Acquire) < 5_000,
        "eager send must not wait for the receiver"
    );
    assert!(rndv_done.load(Ordering::Acquire) >= ms(3), "rendezvous must wait");
}

#[test]
fn bandwidth_shapes_transfer_time() {
    // 1 MiB inter-node at 12.5 GB/s ~ 84 us; recv completion must reflect it.
    let t = Arc::new(AtomicU64::new(0));
    let t2 = t.clone();
    Universe::run(two_ranks(), move |ctx| {
        if ctx.rank == 0 {
            let big = vec![1f32; 1 << 18]; // 1 MiB
            ctx.comm.send(&big, 1, 0);
        } else {
            let mut big = vec![0f32; 1 << 18];
            ctx.comm.recv(&mut big, 0, 0);
            t2.store(ctx.clock.now(), Ordering::Release);
        }
    })
    .unwrap();
    let got = t.load(Ordering::Acquire);
    assert!((us(80)..us(120)).contains(&got), "1 MiB took {got} ns");
}

#[test]
fn self_send_recv_works() {
    Universe::run(ClusterConfig::new(1, 1, 0), |ctx| {
        let r = ctx.comm.isend(&[5i32], 0, 0);
        let mut b = [0i32];
        ctx.comm.recv(&mut b, 0, 0);
        r.wait(&ctx.clock);
        assert_eq!(b[0], 5);
    })
    .unwrap();
}

#[test]
fn deadlock_detection_section5() {
    // Section 5: matching blocking ssend/recv issued from one thread in
    // the wrong order with no progress mechanism => certain deadlock.
    let err = Universe::run(ClusterConfig::new(1, 1, 0), |ctx| {
        ctx.comm.ssend(&[1u8], 0, 0); // blocks forever: recv never posted
        let mut b = [0u8];
        ctx.comm.recv(&mut b, 0, 0);
    })
    .unwrap_err();
    assert!(matches!(
        err,
        tampi_repro::rmpi::universe::RunError::Deadlock { .. }
    ));
}

#[test]
fn barrier_synchronizes() {
    let n = 5;
    let t_after = Arc::new(Mutex::new(vec![0u64; n]));
    let t2 = t_after.clone();
    Universe::run(ClusterConfig::new(n, 1, 0), move |ctx| {
        // Stagger arrival; everyone leaves >= the slowest arrival.
        ctx.clock.sleep(ms(ctx.rank as u64));
        ctx.comm.barrier();
        t2.lock().unwrap()[ctx.rank] = ctx.clock.now();
    })
    .unwrap();
    for &t in t_after.lock().unwrap().iter() {
        assert!(t >= ms((n - 1) as u64), "left barrier at {t}");
    }
}

#[test]
fn bcast_from_each_root() {
    let n = 4;
    for root in 0..n {
        Universe::run(ClusterConfig::new(n, 1, 0), move |ctx| {
            let mut buf = if ctx.rank == root {
                [13i64, -7, root as i64]
            } else {
                [0, 0, 0]
            };
            ctx.comm.bcast(&mut buf, root);
            assert_eq!(buf, [13, -7, root as i64]);
        })
        .unwrap();
    }
}

#[test]
fn reduce_and_allreduce_sum() {
    let n = 6;
    Universe::run(ClusterConfig::new(n, 1, 0), move |ctx| {
        let mut v = [ctx.rank as f64 + 1.0, 1.0];
        ctx.comm.reduce(&mut v, 0, |acc, x| {
            for (a, b) in acc.iter_mut().zip(x) {
                *a += b;
            }
        });
        if ctx.rank == 0 {
            assert_eq!(v, [21.0, 6.0]); // 1+..+6, 6x1
        }
        let mut w = [ctx.rank as f64];
        ctx.comm.allreduce(&mut w, |acc, x| acc[0] += x[0]);
        assert_eq!(w[0], 15.0); // 0+..+5
    })
    .unwrap();
}

#[test]
fn gather_collects_in_rank_order() {
    let n = 5;
    Universe::run(ClusterConfig::new(n, 1, 0), move |ctx| {
        let mine = [ctx.rank as i32 * 2, ctx.rank as i32 * 2 + 1];
        if ctx.rank == 2 {
            let mut all = vec![0i32; 2 * n];
            ctx.comm.gather(&mine, Some(&mut all), 2);
            assert_eq!(all, (0..2 * n as i32).collect::<Vec<_>>());
        } else {
            ctx.comm.gather(&mine, None, 2);
        }
    })
    .unwrap();
}

#[test]
fn alltoall_transposes() {
    let n = 4;
    Universe::run(ClusterConfig::new(n, 1, 0), move |ctx| {
        // send[j] = rank*10 + j ; after alltoall recv[j] = j*10 + rank
        let send: Vec<i32> = (0..n as i32).map(|j| ctx.rank as i32 * 10 + j).collect();
        let mut recv = vec![0i32; n];
        ctx.comm.alltoall(&send, &mut recv);
        let want: Vec<i32> = (0..n as i32).map(|j| j * 10 + ctx.rank as i32).collect();
        assert_eq!(recv, want);
    })
    .unwrap();
}

#[test]
fn waitany_returns_a_completed_request() {
    Universe::run(two_ranks(), |ctx| {
        if ctx.rank == 0 {
            ctx.clock.sleep(ms(2));
            ctx.comm.send(&[1i32], 1, 1);
            // Wait for the ack before satisfying the decoy receive, so
            // rank 1's wait_any observes exactly one completed request.
            let mut ack = [0u8];
            ctx.comm.recv(&mut ack, 1, 9);
            ctx.comm.send(&[0i32], 1, 0);
        } else {
            let mut a = [0i32];
            let mut b = [0i32];
            let r1 = ctx.comm.irecv(&mut a, 0, 0);
            let r2 = ctx.comm.irecv(&mut b, 0, 1);
            let idx = Request::wait_any(&ctx.clock, &[r1.clone(), r2.clone()]);
            assert_eq!(idx, 1);
            assert!(!r1.test());
            ctx.comm.send(&[1u8], 0, 9); // ack
            r1.wait(&ctx.clock);
        }
    })
    .unwrap();
}

#[test]
fn comm_dup_isolates_traffic() {
    Universe::run(two_ranks(), |ctx| {
        let dup = ctx.comm.dup();
        if ctx.rank == 0 {
            ctx.comm.send(&[1i32], 1, 0);
            dup.send(&[2i32], 1, 0);
        } else {
            // Same (src, tag) on both comms: each recv must see its own.
            let mut a = [0i32];
            let mut b = [0i32];
            dup.recv(&mut b, 0, 0);
            ctx.comm.recv(&mut a, 0, 0);
            assert_eq!((a[0], b[0]), (1, 2));
        }
    })
    .unwrap();
}

#[test]
fn instant_network_zero_latency() {
    let mut cfg = two_ranks();
    cfg.net = NetworkModel::instant();
    let stats = Universe::run(cfg, |ctx| {
        if ctx.rank == 0 {
            ctx.comm.send(&[1u8; 128], 1, 0);
        } else {
            let mut b = [0u8; 128];
            ctx.comm.recv(&mut b, 0, 0);
        }
    })
    .unwrap();
    assert_eq!(stats.vtime_ns, 0);
}

#[test]
fn large_cluster_smoke_ring() {
    // 16 nodes x 4 ranks: each rank sends to its successor around a ring.
    let cfg = ClusterConfig::new(16, 4, 0);
    let n = cfg.size();
    Universe::run(cfg, move |ctx| {
        let next = (ctx.rank + 1) % n;
        let prev = (ctx.rank + n - 1) % n;
        let s = ctx.comm.isend(&[ctx.rank as u64], next, 0);
        let mut b = [0u64];
        ctx.comm.recv(&mut b, prev as i32, 0);
        s.wait(&ctx.clock);
        assert_eq!(b[0], prev as u64);
    })
    .unwrap();
}
