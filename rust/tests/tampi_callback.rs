//! Completion-callback notification pipeline: request continuations
//! replacing TAMPI's poll-scan tickets.
//!
//! Covers the `rmpi` continuation primitive itself, the TAMPI callback
//! mode built on it, and mode equivalence (polling vs callback must
//! produce identical MPI-visible results — only notification latency
//! differs).

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use tampi_repro::nanos::{self, CompletionMode, Mode};
use tampi_repro::rmpi::{ClusterConfig, Status, ThreadLevel, Universe, ANY_SOURCE};
use tampi_repro::sim::{ms, us};
use tampi_repro::tampi;

fn cfg_with_mode(nodes: usize, cores: usize, mode: CompletionMode) -> ClusterConfig {
    ClusterConfig::new(nodes, 1, cores).with_completion_mode(mode)
}

#[test]
fn continuation_attached_after_completion_fires_inline() {
    Universe::run(ClusterConfig::new(2, 1, 0), |ctx| {
        if ctx.rank == 0 {
            let mut b = [0i32; 2];
            let r = ctx.comm.irecv(&mut b, 1, 7);
            r.wait(&ctx.clock);
            // Attach after completion: must run inline with final status.
            let fired = Arc::new(AtomicU32::new(0));
            let f2 = fired.clone();
            r.on_complete(move |st| {
                assert_eq!((st.source, st.tag, st.bytes), (1, 7, 8));
                f2.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(fired.load(Ordering::Relaxed), 1, "must fire inline");
            assert_eq!(b, [5, 6]);
        } else {
            ctx.comm.send(&[5i32, 6], 0, 7);
        }
    })
    .unwrap();
}

#[test]
fn continuation_fires_at_the_virtual_completion_instant() {
    let fired_at = Arc::new(AtomicU64::new(0));
    let f2 = fired_at.clone();
    Universe::run(ClusterConfig::new(2, 1, 0), move |ctx| {
        if ctx.rank == 0 {
            let mut b = [0u8];
            let r = ctx.comm.irecv(&mut b, 1, 0);
            let clock = ctx.clock.clone();
            let f = f2.clone();
            r.on_complete(move |st| {
                assert_eq!(st.bytes, 1);
                f.store(clock.now(), Ordering::Release);
            });
            r.wait(&ctx.clock);
        } else {
            ctx.clock.sleep(ms(4));
            ctx.comm.send(&[1u8], 0, 0);
        }
    })
    .unwrap();
    let t = fired_at.load(Ordering::Acquire);
    assert!(t >= ms(4), "continuation fired at {t} ns, before the message existed");
    assert!(t < ms(5), "continuation fired at {t} ns, long after arrival");
}

#[test]
fn mixed_immediate_and_deferred_iwaitall_under_callback_mode() {
    let done_t = Arc::new(AtomicU64::new(0));
    let d2 = done_t.clone();
    let stats = Universe::run(
        cfg_with_mode(3, 1, CompletionMode::Callback),
        move |ctx| {
            let rt = ctx.rt.as_ref().unwrap();
            let t = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
            assert_eq!(t.mode(), CompletionMode::Callback);
            if ctx.rank == 0 {
                // Let rank 1's eager message arrive before the task posts
                // its receive: one request of the iwaitall is then already
                // complete (immediate), the other still in flight.
                ctx.clock.sleep(ms(2));
                let bufs: Arc<Mutex<([i32; 1], [i32; 1])>> =
                    Arc::new(Mutex::new(([0], [0])));
                let obj = rt.dep("bufs");
                let (t1, b1) = (t.clone(), bufs.clone());
                rt.task().dep(&obj, Mode::Out).spawn(move || {
                    let mut g = b1.lock().unwrap();
                    let (ref mut a, ref mut b) = *g;
                    let r1 = t1.comm().irecv(a, 1, 0);
                    let r2 = t1.comm().irecv(b, 2, 0);
                    drop(g);
                    assert!(r1.test(), "rank 1's message must already be here");
                    assert!(!r2.test(), "rank 2's message must still be in flight");
                    t1.iwaitall(&[r1, r2]);
                });
                let (d, b2) = (d2.clone(), bufs.clone());
                rt.task().dep(&obj, Mode::In).spawn(move || {
                    let g = b2.lock().unwrap();
                    assert_eq!((g.0[0], g.1[0]), (111, 222));
                    d.store(nanos::current_clock().now(), Ordering::Release);
                });
            } else if ctx.rank == 1 {
                ctx.comm.send(&[111i32], 0, 0);
            } else {
                ctx.clock.sleep(ms(8));
                ctx.comm.send(&[222i32], 0, 0);
            }
        },
    )
    .unwrap();
    assert!(done_t.load(Ordering::Acquire) >= ms(8), "release gated by the slow request");
    assert_eq!(stats.pauses, 0, "non-blocking mode must not pause tasks");
}

#[test]
fn wildcard_source_recv_under_callback_mode() {
    let seen: Arc<Mutex<Option<Status>>> = Arc::new(Mutex::new(None));
    let s2 = seen.clone();
    let stats = Universe::run(
        cfg_with_mode(2, 1, CompletionMode::Callback),
        move |ctx| {
            let rt = ctx.rt.as_ref().unwrap();
            let t = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
            if ctx.rank == 0 {
                let (t1, s) = (t.clone(), s2.clone());
                rt.task().label("recv-any").spawn(move || {
                    let mut b = [0i32; 3];
                    let st = t1.recv(&mut b, ANY_SOURCE, 5);
                    assert_eq!(b, [7, 8, 9]);
                    *s.lock().unwrap() = Some(st);
                });
            } else {
                ctx.clock.sleep(ms(3));
                ctx.comm.send(&[7i32, 8, 9], 0, 5);
            }
        },
    )
    .unwrap();
    let st = seen.lock().unwrap().expect("recv task must have run");
    assert_eq!((st.source, st.tag, st.bytes), (1, 5, 12));
    assert!(stats.pauses >= 1, "the recv task must have paused until delivery");
}

/// One mixed scenario (wildcard + specific sources, varied sizes and
/// delays), returning the MPI-visible outcome: per-tag `Status` plus
/// received payload sums, and the per-pipeline delivery counts.
fn mixed_scenario(mode: CompletionMode) -> (Vec<(i32, i32, usize, i64)>, u64, u64) {
    const N: usize = 6;
    let results: Arc<Mutex<Vec<(i32, i32, usize, i64)>>> =
        Arc::new(Mutex::new(vec![(0, 0, 0, 0); N]));
    let deliveries = Arc::new((AtomicU64::new(0), AtomicU64::new(0)));
    let (r2, d2) = (results.clone(), deliveries.clone());
    Universe::run(cfg_with_mode(3, 2, mode), move |ctx| {
        let rt = ctx.rt.as_ref().unwrap();
        let t = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
        if ctx.rank == 0 {
            for i in 0..N {
                let (t1, res) = (t.clone(), r2.clone());
                rt.task().label(format!("recv{i}")).spawn(move || {
                    let mut b = vec![0i32; i + 1];
                    // Even tags come from rank 1 and use a wildcard
                    // source; odd tags name rank 2 explicitly.
                    let src = if i % 2 == 0 { ANY_SOURCE } else { 2 };
                    let st = t1.recv(&mut b, src, i as i32);
                    let sum: i64 = b.iter().map(|&x| x as i64).sum();
                    res.lock().unwrap()[i] = (st.source, st.tag, st.bytes, sum);
                });
            }
            rt.taskwait();
            let (poll, cb) = t.mode_stats();
            d2.0.store(poll, Ordering::Release);
            d2.1.store(cb, Ordering::Release);
        } else {
            // rank 1 owns even tags, rank 2 odd tags; staggered sends.
            let first = if ctx.rank == 1 { 0 } else { 1 };
            for i in (first..N).step_by(2) {
                ctx.clock.sleep(ms(1));
                let payload = vec![i as i32; i + 1];
                ctx.comm.send(&payload, 0, i as i32);
            }
        }
    })
    .unwrap();
    let out = results.lock().unwrap().clone();
    (
        out,
        deliveries.0.load(Ordering::Acquire),
        deliveries.1.load(Ordering::Acquire),
    )
}

#[test]
fn polling_and_callback_modes_produce_identical_results() {
    let (poll_out, poll_by_scan, poll_by_cb) = mixed_scenario(CompletionMode::Polling);
    let (cb_out, cb_by_scan, cb_by_cb) = mixed_scenario(CompletionMode::Callback);
    assert_eq!(poll_out, cb_out, "MPI-visible results must not depend on the pipeline");
    for (i, (source, tag, bytes, sum)) in poll_out.iter().enumerate() {
        let want_src = if i % 2 == 0 { 1 } else { 2 };
        assert_eq!(*source, want_src, "tag {i}");
        assert_eq!(*tag, i as i32);
        assert_eq!(*bytes, (i + 1) * 4);
        assert_eq!(*sum, (i * (i + 1)) as i64);
    }
    // Each pipeline must have delivered through its own path only.
    assert_eq!(poll_by_cb, 0, "polling mode must not use continuations");
    assert!(poll_by_scan > 0, "polling mode must retire tickets via the scan");
    assert_eq!(cb_by_scan, 0, "callback mode must not poll-scan");
    assert!(cb_by_cb > 0, "callback mode must deliver via continuations");
}

// The virtual-time completion→resume latency scenario lives in
// `tampi_repro::bench::completion_latency_ns` (shared with
// `benches/micro_runtime.rs` so the calibrated setup exists once).

#[test]
fn per_handle_polling_override_governs_collectives_on_a_callback_runtime() {
    // init_with_mode pins the pipeline per handle; the override must also
    // reach the handle's collective waits (WaitMode::TaskAware carries it).
    let n = 4;
    let sum = Arc::new(AtomicU32::new(0));
    let s2 = sum.clone();
    Universe::run(
        cfg_with_mode(n, 1, CompletionMode::Callback),
        move |ctx| {
            let rt = ctx.rt.as_ref().unwrap();
            let t = tampi::init_with_mode(
                &ctx.comm,
                rt,
                ThreadLevel::TaskMultiple,
                CompletionMode::Polling,
            );
            assert_eq!(t.mode(), CompletionMode::Polling);
            let rank = ctx.rank;
            let s = s2.clone();
            rt.task().label("coll").spawn(move || {
                t.barrier();
                let mut v = [rank as u64];
                t.allreduce(&mut v, |a, b| a[0] += b[0]);
                s.fetch_add(v[0] as u32, Ordering::Relaxed);
            });
        },
    )
    .unwrap();
    assert_eq!(sum.load(Ordering::Relaxed), 6 * n as u32);
}

#[test]
fn callback_mode_retires_recv_in_under_one_poll_interval() {
    let cb = tampi_repro::bench::completion_latency_ns(CompletionMode::Callback);
    let poll = tampi_repro::bench::completion_latency_ns(CompletionMode::Polling);
    assert!(
        cb < us(50),
        "callback-mode completion-to-resume latency {cb} ns must be under one \
         poll_interval (50 us)"
    );
    assert!(
        cb <= poll,
        "callback mode ({cb} ns) must not be slower than the poll-scan ({poll} ns)"
    );
}
