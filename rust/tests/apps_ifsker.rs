//! IFSKer application: version equivalence and the Section 7.2 shape.

use tampi_repro::apps::ifsker::{run, IfsParams, IfsVersion};
use tampi_repro::apps::Compute;
use tampi_repro::sim::ms;

fn base(version: IfsVersion) -> IfsParams {
    // 4 ranks (2 nodes x 2), 512 gridpoints, 4 fields, 3 steps.
    let mut p = IfsParams::new(512, 4, 3, 2, 2, version);
    p.deadline = Some(ms(60_000));
    p
}

#[test]
fn all_versions_agree_bitwise() {
    let pure = run(&base(IfsVersion::PureMpi)).unwrap();
    assert!(pure.checksum > 0.0);
    for v in [IfsVersion::InteropBlk, IfsVersion::InteropNonBlk] {
        let out = run(&base(v)).unwrap_or_else(|e| panic!("{}: {e}", v.name()));
        assert_eq!(
            out.checksum.to_bits(),
            pure.checksum.to_bits(),
            "{} diverged: {} vs {}",
            v.name(),
            out.checksum,
            pure.checksum
        );
    }
}

#[test]
fn physics_changes_fields_each_step() {
    let a = run(&base(IfsVersion::PureMpi)).unwrap();
    let mut p = base(IfsVersion::PureMpi);
    p.steps = 6;
    let b = run(&p).unwrap();
    assert_ne!(a.checksum.to_bits(), b.checksum.to_bits());
}

#[test]
fn interop_beats_pure_across_nodes() {
    // Section 7.2's shape: tasks overlap the many small transposition
    // messages with compute; the gap is structural once wire latency is
    // on the critical path (multi-node).
    let mk = |v| {
        let mut p = IfsParams::new(32 * 1024, 8, 4, 2, 8, v);
        p.compute = Compute::Model;
        p.deadline = Some(ms(600_000));
        run(&p).unwrap().vtime_ns
    };
    let pure = mk(IfsVersion::PureMpi);
    let blk = mk(IfsVersion::InteropBlk);
    let nblk = mk(IfsVersion::InteropNonBlk);
    assert!(
        blk < pure,
        "interop-blk ({blk}) must beat pure ({pure}) across nodes"
    );
    assert!(
        nblk < pure,
        "interop-nonblk ({nblk}) must beat pure ({pure}) across nodes"
    );
    // On one node the gap narrows but interop must stay competitive.
    let mk1 = |v| {
        let mut p = IfsParams::new(16 * 1024, 8, 4, 1, 16, v);
        p.compute = Compute::Model;
        p.deadline = Some(ms(600_000));
        run(&p).unwrap().vtime_ns
    };
    let pure1 = mk1(IfsVersion::PureMpi) as f64;
    let blk1 = mk1(IfsVersion::InteropBlk) as f64;
    assert!(
        blk1 < pure1 * 1.5,
        "interop-blk ({blk1}) must stay competitive on one node ({pure1})"
    );
}

#[test]
fn nonblocking_mode_never_pauses() {
    let out = run(&base(IfsVersion::InteropNonBlk)).unwrap();
    assert_eq!(out.stats.pauses, 0);
    let blk = run(&base(IfsVersion::InteropBlk)).unwrap();
    assert!(blk.stats.pauses > 0);
}

#[test]
fn model_mode_runs_at_scale_without_field_memory() {
    let mut p = IfsParams::new(64 * 64, 4, 2, 4, 4, IfsVersion::InteropNonBlk);
    p.compute = Compute::Model;
    p.deadline = Some(ms(600_000));
    let out = run(&p).unwrap();
    assert!(out.vtime_ns > 0);
    assert_eq!(out.checksum, 0.0);
}
