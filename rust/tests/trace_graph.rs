//! Tracing (Fig 10) and dependency graphs (Fig 8).

use std::sync::Arc;

use tampi_repro::apps::gauss_seidel::{run, GsParams, GsVersion};
use tampi_repro::apps::Compute;
use tampi_repro::sim::ms;
use tampi_repro::trace::{busy_fraction, render_gantt, GraphRecorder, Tracer};

fn traced_params(v: GsVersion, tracer: Option<Arc<Tracer>>, graph: Option<Arc<GraphRecorder>>) -> GsParams {
    let mut p = GsParams::new(128, 384, 32, 3, 4, 2, v); // Fig 7/8's 3x12 blocks
    p.compute = Compute::Model;
    p.tracer = tracer;
    p.graph = graph;
    p.deadline = Some(ms(600_000));
    p
}

#[test]
fn tracer_captures_task_and_mpi_events() {
    let tracer = Arc::new(Tracer::new());
    run(&traced_params(GsVersion::InteropBlk, Some(tracer.clone()), None)).unwrap();
    let recs = tracer.snapshot();
    assert!(!recs.is_empty());
    let kinds: std::collections::HashSet<&str> =
        recs.iter().map(|r| r.kind.as_str()).collect();
    assert!(kinds.contains("task_start"));
    assert!(kinds.contains("task_end"));
    assert!(kinds.contains("task_block"), "TAMPI blocking mode must pause");
    assert!(kinds.contains("task_unblock"));
    // Virtual timestamps are monotone within the snapshot sort.
    let mut last = 0;
    for r in &recs {
        assert!(r.t >= last);
        last = r.t;
    }
}

#[test]
fn gantt_renders_all_lanes() {
    let tracer = Arc::new(Tracer::new());
    run(&traced_params(GsVersion::InteropBlk, Some(tracer.clone()), None)).unwrap();
    let recs = tracer.snapshot();
    let chart = render_gantt(&recs, 80);
    // 4 ranks x >=2 workers -> at least 8 lanes.
    assert!(chart.lines().filter(|l| l.starts_with('r')).count() >= 8, "{chart}");
    assert!(chart.contains('#'), "some task activity expected\n{chart}");
}

#[test]
fn busy_fraction_is_sane() {
    let tracer = Arc::new(Tracer::new());
    run(&traced_params(GsVersion::InteropBlk, Some(tracer.clone()), None)).unwrap();
    let busy = busy_fraction(&tracer.snapshot());
    assert_eq!(busy.len(), 4, "one entry per rank");
    for (&rank, &f) in &busy {
        assert!((0.0..=1.0).contains(&f), "rank {rank} busy {f}");
    }
}

#[test]
fn csv_roundtrip_has_header_and_rows() {
    let tracer = Arc::new(Tracer::new());
    run(&traced_params(GsVersion::Sentinel, Some(tracer.clone()), None)).unwrap();
    let csv = tracer.to_csv();
    let mut lines = csv.lines();
    assert_eq!(lines.next().unwrap(), "t_ns,rank,worker,kind,task_id,label");
    assert!(lines.count() > 10);
}

#[test]
fn sentinel_graph_has_the_red_serialization_edges() {
    // Fig 8: the Sentinel version adds artificial dependencies between
    // communication tasks; Interop removes exactly those.
    let g_sent = Arc::new(GraphRecorder::new());
    run(&traced_params(GsVersion::Sentinel, None, Some(g_sent.clone()))).unwrap();
    let g_int = Arc::new(GraphRecorder::new());
    run(&traced_params(GsVersion::InteropBlk, None, Some(g_int.clone()))).unwrap();

    assert_eq!(
        g_sent.node_count(),
        g_int.node_count(),
        "same task structure"
    );
    assert!(
        g_sent.edge_count() > g_int.edge_count(),
        "sentinel ({}) must add serialization edges over interop ({})",
        g_sent.edge_count(),
        g_int.edge_count()
    );

    let dot = g_sent.to_dot("sentinel");
    assert!(dot.contains("color=red"), "red dependencies must be marked");
    assert!(dot.contains("cluster_rank0") && dot.contains("cluster_rank3"));
    let dot_int = g_int.to_dot("sentinel");
    assert!(!dot_int.contains("color=red"), "interop has no red edges");
}

#[test]
fn graph_is_acyclic() {
    // Kahn's algorithm over the recorded dependency graph.
    let g = Arc::new(GraphRecorder::new());
    run(&traced_params(GsVersion::InteropNonBlk, None, Some(g.clone()))).unwrap();
    let edges = g.edges();
    let mut nodes: std::collections::HashSet<u64> = std::collections::HashSet::new();
    for (a, b) in &edges {
        nodes.insert(*a);
        nodes.insert(*b);
    }
    let mut indeg: std::collections::HashMap<u64, usize> =
        nodes.iter().map(|&n| (n, 0)).collect();
    for (_, b) in &edges {
        *indeg.get_mut(b).unwrap() += 1;
    }
    let mut queue: Vec<u64> = indeg
        .iter()
        .filter(|(_, &d)| d == 0)
        .map(|(&n, _)| n)
        .collect();
    let mut seen = 0;
    let mut adj: std::collections::HashMap<u64, Vec<u64>> = Default::default();
    for (a, b) in &edges {
        adj.entry(*a).or_default().push(*b);
    }
    while let Some(n) = queue.pop() {
        seen += 1;
        for &m in adj.get(&n).into_iter().flatten() {
            let d = indeg.get_mut(&m).unwrap();
            *d -= 1;
            if *d == 0 {
                queue.push(m);
            }
        }
    }
    assert_eq!(seen, nodes.len(), "dependency graph contains a cycle");
}
