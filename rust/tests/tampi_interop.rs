//! TAMPI: blocking mode (MPI_TASK_MULTIPLE) and non-blocking mode
//! (TAMPI_Iwait/Iwaitall) — the paper's Section 6 behaviours.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use tampi_repro::nanos::{self, Mode};
use tampi_repro::rmpi::{ClusterConfig, ThreadLevel, Universe};
use tampi_repro::sim::ms;
use tampi_repro::tampi;

#[test]
fn section5_scenario_resolves_with_task_multiple() {
    // One rank, ONE core, two tasks: blocking ssend + matching recv.
    // Raw MPI deadlocks (see rmpi_basic); with TAMPI the first task pauses
    // and the runtime schedules the second (Section 5's resolution).
    let ok = Arc::new(AtomicU32::new(0));
    let ok2 = ok.clone();
    let stats = Universe::run(ClusterConfig::new(1, 1, 1), move |ctx| {
        let rt = ctx.rt.as_ref().unwrap();
        let t = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
        assert!(t.enabled());
        let t1 = t.clone();
        let ok = ok2.clone();
        rt.task().label("ssend").spawn(move || {
            t1.ssend(&[77i32], 0, 0);
            ok.fetch_add(1, Ordering::Relaxed);
        });
        let t2 = t.clone();
        let ok = ok2.clone();
        rt.task().label("recv").spawn(move || {
            let mut b = [0i32];
            t2.recv(&mut b, 0, 0);
            assert_eq!(b[0], 77);
            ok.fetch_add(1, Ordering::Relaxed);
        });
    })
    .unwrap();
    assert_eq!(ok.load(Ordering::Relaxed), 2);
    assert!(stats.pauses >= 1, "the ssend task must have paused");
    assert!(stats.workers >= 2, "a substitute worker must exist");
}

#[test]
fn blocking_mode_overlaps_communication_with_compute() {
    // Rank 0: one comm task waiting for a late message + compute tasks.
    // With 1 core, the comm task's pause lets compute proceed -> makespan
    // ~= message delay, not delay + compute.
    let stats = Universe::run(ClusterConfig::new(2, 1, 1), |ctx| {
        let rt = ctx.rt.as_ref().unwrap();
        let t = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
        if ctx.rank == 0 {
            let t1 = t.clone();
            rt.task().label("recv").spawn(move || {
                let mut b = [0u8];
                t1.recv(&mut b, 1, 0);
            });
            for _ in 0..10 {
                rt.task().label("compute").spawn(|| nanos::work(ms(1)));
            }
        } else {
            ctx.clock.sleep(ms(10));
            ctx.comm.send(&[1u8], 0, 0);
        }
    })
    .unwrap();
    // Compute (10 x 1ms) overlaps the 10ms wait entirely.
    assert!(
        stats.vtime_ns < ms(13),
        "no overlap: took {} ms",
        stats.vtime_ns / 1_000_000
    );
}

#[test]
fn fallback_level_disables_interop() {
    Universe::run(ClusterConfig::new(1, 1, 1), |ctx| {
        let rt = ctx.rt.as_ref().unwrap();
        let t = tampi::init(&ctx.comm, rt, ThreadLevel::Multiple);
        assert!(!t.enabled());
        assert_eq!(t.level(), ThreadLevel::Multiple);
    })
    .unwrap();
}

#[test]
fn iwait_defers_dependency_release_until_completion() {
    // Fig 5's pattern: a comm task irecvs + iwaits; a consumer task with
    // an `in` dep on the buffer object prints/checks the value. The
    // consumer must only run after the message really arrived (t=6ms),
    // even though the comm task finishes instantly.
    let consumer_t = Arc::new(AtomicU64::new(0));
    let seen = Arc::new(AtomicU32::new(0));
    let (ct2, s2) = (consumer_t.clone(), seen.clone());
    let stats = Universe::run(ClusterConfig::new(2, 1, 1), move |ctx| {
        let rt = ctx.rt.as_ref().unwrap();
        let t = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
        if ctx.rank == 0 {
            // Shared buffer whose lifetime spans the tasks.
            let buf: Arc<Mutex<[i32; 1]>> = Arc::new(Mutex::new([0i32]));
            let obj = rt.dep("buf");
            let (t1, b1) = (t.clone(), buf.clone());
            rt.task()
                .label("comm")
                .dep(&obj, Mode::Out)
                .spawn(move || {
                    let mut g = b1.lock().unwrap();
                    let r = t1.comm().irecv(&mut *g, 1, 0);
                    drop(g); // release the lock; rmpi owns the buffer now
                    t1.iwait(&r);
                    // returns immediately; deps held by the external event
                });
            let (ct, s, b2) = (ct2.clone(), s2.clone(), buf.clone());
            rt.task()
                .label("consume")
                .dep(&obj, Mode::In)
                .spawn(move || {
                    ct.store(nanos::current_clock().now(), Ordering::Release);
                    s.store(b2.lock().unwrap()[0] as u32, Ordering::Release);
                });
        } else {
            ctx.clock.sleep(ms(6));
            ctx.comm.send(&[1234i32], 0, 0);
        }
    })
    .unwrap();
    assert_eq!(seen.load(Ordering::Acquire), 1234);
    assert!(
        consumer_t.load(Ordering::Acquire) >= ms(6),
        "consumer ran before the message arrived"
    );
    assert_eq!(stats.pauses, 0, "non-blocking mode must not pause tasks");
}

#[test]
fn iwaitall_binds_multiple_requests() {
    let done_t = Arc::new(AtomicU64::new(0));
    let d2 = done_t.clone();
    Universe::run(ClusterConfig::new(3, 1, 1), move |ctx| {
        let rt = ctx.rt.as_ref().unwrap();
        let t = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
        if ctx.rank == 0 {
            let bufs: Arc<Mutex<([i32; 1], [i32; 1])>> =
                Arc::new(Mutex::new(([0], [0])));
            let obj = rt.dep("bufs");
            let (t1, b1) = (t.clone(), bufs.clone());
            rt.task().dep(&obj, Mode::Out).spawn(move || {
                let mut g = b1.lock().unwrap();
                let (ref mut a, ref mut b) = *g;
                let r1 = t1.comm().irecv(a, 1, 0);
                let r2 = t1.comm().irecv(b, 2, 0);
                drop(g);
                t1.iwaitall(&[r1, r2]);
            });
            let (d, b2) = (d2.clone(), bufs.clone());
            rt.task().dep(&obj, Mode::In).spawn(move || {
                let g = b2.lock().unwrap();
                assert_eq!((g.0[0], g.1[0]), (111, 222));
                d.store(nanos::current_clock().now(), Ordering::Release);
            });
        } else if ctx.rank == 1 {
            ctx.clock.sleep(ms(2));
            ctx.comm.send(&[111i32], 0, 0);
        } else {
            ctx.clock.sleep(ms(8)); // the slower of the two gates release
            ctx.comm.send(&[222i32], 0, 0);
        }
    })
    .unwrap();
    assert!(done_t.load(Ordering::Acquire) >= ms(8));
}

#[test]
fn both_modes_coexist() {
    // Section 6.2: blocking and non-blocking modes are compatible.
    let hits = Arc::new(AtomicU32::new(0));
    let h2 = hits.clone();
    Universe::run(ClusterConfig::new(2, 1, 2), move |ctx| {
        let rt = ctx.rt.as_ref().unwrap();
        let t = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
        if ctx.rank == 0 {
            let buf: Arc<Mutex<[i32; 1]>> = Arc::new(Mutex::new([0]));
            let obj = rt.dep("b");
            let (t1, b1) = (t.clone(), buf.clone());
            rt.task().dep(&obj, Mode::Out).spawn(move || {
                let mut g = b1.lock().unwrap();
                let r = t1.comm().irecv(&mut *g, 1, 1);
                drop(g);
                t1.iwait(&r); // non-blocking mode
            });
            let t2 = t.clone();
            let h = h2.clone();
            rt.task().dep(&obj, Mode::In).spawn(move || {
                let mut b = [0i32];
                t2.recv(&mut b, 1, 2); // blocking mode inside a task
                h.fetch_add(b[0] as u32, Ordering::Relaxed);
            });
        } else {
            ctx.clock.sleep(ms(1));
            ctx.comm.send(&[7i32], 0, 1);
            ctx.clock.sleep(ms(1));
            ctx.comm.send(&[35i32], 0, 2);
        }
    })
    .unwrap();
    assert_eq!(hits.load(Ordering::Relaxed), 35);
}

#[test]
fn task_aware_collectives() {
    // Barrier + allreduce from inside tasks with TAMPI: uses task-aware
    // waiting instead of parking worker threads.
    let n = 4;
    let sum = Arc::new(AtomicU32::new(0));
    let s2 = sum.clone();
    Universe::run(ClusterConfig::new(n, 1, 1), move |ctx| {
        let rt = ctx.rt.as_ref().unwrap();
        let t = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
        let rank = ctx.rank;
        let s = s2.clone();
        rt.task().label("coll").spawn(move || {
            t.barrier();
            let mut v = [rank as u64];
            t.allreduce(&mut v, |a, b| a[0] += b[0]);
            s.fetch_add(v[0] as u32, Ordering::Relaxed);
        });
    })
    .unwrap();
    // each rank contributes 0+1+2+3 = 6
    assert_eq!(sum.load(Ordering::Relaxed), 6 * n as u32);
}

#[test]
fn many_inflight_small_messages_nonblocking_cheaper_than_blocking() {
    // Section 6.2's motivation: many communication tasks with small
    // messages. Blocking mode pays pauses + substitute workers; the
    // non-blocking mode pays neither.
    let run = |nonblocking: bool| {
        Universe::run(ClusterConfig::new(2, 1, 2), move |ctx| {
            let rt = ctx.rt.as_ref().unwrap();
            let t = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
            let m = 32;
            if ctx.rank == 0 {
                for i in 0..m {
                    let t1 = t.clone();
                    rt.task().label(format!("recv{i}")).spawn(move || {
                        let mut b = [0i32];
                        if nonblocking {
                            let r = t1.comm().irecv(&mut b, 1, i);
                            t1.iwait(&r);
                            // NOTE: b dies with the task; fine for the test
                            // since nobody consumes it.
                        } else {
                            t1.recv(&mut b, 1, i);
                        }
                    });
                }
            } else {
                ctx.clock.sleep(ms(5));
                for i in 0..m {
                    ctx.comm.send(&[i], 0, i);
                }
            }
        })
        .unwrap()
    };
    let blk = run(false);
    let nblk = run(true);
    assert!(blk.pauses >= 16, "blocking mode must pause tasks");
    assert_eq!(nblk.pauses, 0, "non-blocking mode must not pause");
    assert!(
        nblk.workers < blk.workers,
        "non-blocking needs fewer threads ({} vs {})",
        nblk.workers,
        blk.workers
    );
}
