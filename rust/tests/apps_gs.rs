//! Gauss-Seidel application: correctness (all six versions bit-identical
//! to a serial reference) and the paper's qualitative performance shape.

use tampi_repro::apps::gauss_seidel::{run, sweep_native, GsParams, GsVersion};
use tampi_repro::apps::Compute;
use tampi_repro::sim::ms;

/// Serial reference: full-grid in-place sweeps (the literal algorithm).
fn serial_checksum(rows: usize, cols: usize, iters: usize) -> f64 {
    let mut u = vec![0f32; rows * cols];
    let top = vec![1f32; cols]; // heat source
    let bot = vec![0f32; cols];
    let side = vec![0f32; rows];
    for _ in 0..iters {
        sweep_native(&mut u, rows, cols, &top, &bot, &side, &side);
    }
    u.iter().map(|&x| x as f64).sum()
}

fn base_params(version: GsVersion) -> GsParams {
    // 64 x 128 grid, 32-blocks, 2 nodes x 2 cores, 6 iterations.
    let mut p = GsParams::new(64, 128, 32, 6, 2, 2, version);
    p.deadline = Some(ms(60_000)); // hang guard
    p
}

#[test]
fn all_versions_match_serial_reference() {
    let want = serial_checksum(64, 128, 6);
    assert!(want > 0.0);
    for v in GsVersion::all() {
        let out = run(&base_params(v)).unwrap_or_else(|e| panic!("{}: {e}", v.name()));
        // The f32 grids are identical cell-for-cell; only the f64
        // reduction order of the checksum differs per decomposition.
        let rel = (out.checksum - want).abs() / want;
        assert!(
            rel < 1e-10,
            "{} produced {} instead of {} (rel {rel:e})",
            v.name(),
            out.checksum,
            want
        );
    }
}

#[test]
fn single_node_single_core_degenerate() {
    // 1 node, 1 core: every version degenerates to serial; still correct.
    let want = serial_checksum(32, 32, 4);
    for v in GsVersion::all() {
        let mut p = GsParams::new(32, 32, 16, 4, 1, 1, v);
        p.deadline = Some(ms(60_000));
        let out = run(&p).unwrap_or_else(|e| panic!("{}: {e}", v.name()));
        let rel = (out.checksum - want).abs() / want.max(1e-12);
        assert!(rel < 1e-10, "{}: {} vs {want}", v.name(), out.checksum);
    }
}

#[test]
fn heat_propagates_from_top_boundary() {
    let out = run(&base_params(GsVersion::InteropBlk)).unwrap();
    assert!(out.checksum > 0.0, "heat must flow into the grid");
    // More iterations -> more heat absorbed.
    let mut p = base_params(GsVersion::InteropBlk);
    p.iters = 12;
    let out2 = run(&p).unwrap();
    assert!(out2.checksum > out.checksum);
}

#[test]
fn interop_blocking_pauses_tasks_and_nonblocking_does_not() {
    let blk = run(&base_params(GsVersion::InteropBlk)).unwrap();
    let nblk = run(&base_params(GsVersion::InteropNonBlk)).unwrap();
    assert!(blk.stats.pauses > 0, "blocking mode must pause comm tasks");
    assert_eq!(nblk.stats.pauses, 0, "non-blocking mode must not pause");
    assert!(
        nblk.stats.workers <= blk.stats.workers,
        "non-blocking must not need more substitute workers"
    );
}

#[test]
fn sentinel_does_not_pause_but_still_completes() {
    let out = run(&base_params(GsVersion::Sentinel)).unwrap();
    assert_eq!(out.stats.pauses, 0, "sentinel uses raw blocking calls");
}

/// The paper's headline shape (Fig 9): with several nodes, the Interop
/// versions beat Sentinel and Fork-Join, and Fork-Join is the slowest
/// task-based version. Model compute, scaled-down cluster.
#[test]
fn performance_shape_across_versions() {
    let mut times = std::collections::HashMap::new();
    for v in [
        GsVersion::ForkJoin,
        GsVersion::Sentinel,
        GsVersion::InteropBlk,
        GsVersion::InteropNonBlk,
    ] {
        // 1024 x 1024, 128-blocks (8x8 blocks), 4 nodes x 4 cores, model.
        let mut p = GsParams::new(1024, 1024, 128, 30, 4, 4, v);
        p.compute = Compute::Model;
        p.deadline = Some(ms(600_000));
        let out = run(&p).unwrap_or_else(|e| panic!("{}: {e}", v.name()));
        times.insert(v.name(), out.vtime_ns);
    }
    let fj = times["fork-join"];
    let se = times["sentinel"];
    let ib = times["interop-blk"];
    let inb = times["interop-nonblk"];
    assert!(
        ib < se && ib < fj,
        "interop-blk ({ib}) must beat sentinel ({se}) and fork-join ({fj})"
    );
    assert!(
        inb < se && inb < fj,
        "interop-nonblk ({inb}) must beat sentinel ({se}) and fork-join ({fj})"
    );
}

/// Hybrid versions on one node avoid MPI entirely and exploit the
/// temporal wavefront; Fork-Join's per-iteration join forfeits it.
#[test]
fn single_node_hybrid_beats_forkjoin() {
    let run_v = |v| {
        let mut p = GsParams::new(512, 512, 128, 20, 1, 4, v);
        p.compute = Compute::Model;
        p.deadline = Some(ms(600_000));
        run(&p).unwrap().vtime_ns
    };
    let fj = run_v(GsVersion::ForkJoin);
    let ib = run_v(GsVersion::InteropBlk);
    assert!(
        ib < fj,
        "interop ({ib}) must beat fork-join ({fj}) on one node"
    );
}

#[test]
fn model_and_native_have_same_virtual_time() {
    // The cost model drives virtual time; numerics must not change it.
    let mut p1 = base_params(GsVersion::InteropNonBlk);
    p1.compute = Compute::Native;
    let mut p2 = base_params(GsVersion::InteropNonBlk);
    p2.compute = Compute::Model;
    let a = run(&p1).unwrap().vtime_ns;
    let b = run(&p2).unwrap().vtime_ns;
    let ratio = a as f64 / b as f64;
    assert!(
        (0.7..1.3).contains(&ratio),
        "native {a} vs model {b} virtual time diverged"
    );
}
