//! Observability-layer tests on real application runs: attaching a span
//! sink must never perturb virtual time (bit-identity with tracing off),
//! a traced run must emit every instrumented span kind, the Perfetto
//! export must be structurally sound with cross-rank flow arrows, the
//! overlap profiler must rank non-blocking TAMPI above blocking, and
//! the metrics registry must ride `RunStats` in every run.

use std::collections::BTreeSet;
use std::sync::Arc;

use tampi_repro::apps::gauss_seidel::{self, GsParams, GsVersion};
use tampi_repro::apps::ifsker::{self, IfsParams, IfsVersion};
use tampi_repro::apps::Compute;
use tampi_repro::obs::{overlap, perfetto, SpanKind, SpanSink};
use tampi_repro::sim::ms;

/// A small gs config that exercises every instrumented subsystem:
/// ingress-port service (`rx_ns > 0`), sharded clock lanes, residual
/// collectives, and in-task MPI.
fn gs_params(version: GsVersion, spans: Option<Arc<SpanSink>>) -> GsParams {
    let mut p = GsParams::new(128, 128, 32, 4, 2, 2, version);
    p.compute = Compute::Native; // real checksums for the bit-identity test
    p.net.rx_ns = 200;
    p.clock_shards = 2;
    p.residual_every = 2;
    p.residual_nonblocking = version == GsVersion::InteropNonBlk;
    p.spans = spans;
    p.deadline = Some(ms(60_000));
    p
}

fn ifs_params(version: IfsVersion, spans: Option<Arc<SpanSink>>) -> IfsParams {
    // gridpoints must be divisible by ranks and the per-rank share by
    // ranks again (the transposition re-splits it).
    let mut p = IfsParams::new(64, 2, 4, 2, 2, version);
    p.compute = Compute::Native;
    p.net.rx_ns = 200;
    p.clock_shards = 2;
    p.residual_every = 2;
    p.residual_nonblocking = version == IfsVersion::InteropNonBlk;
    p.spans = spans;
    p.deadline = Some(ms(60_000));
    p
}

/// The deterministic projection of an outcome: everything virtual-time
/// derived. Host-scheduling-dependent stats (steals, delivery batches,
/// clock events) are deliberately excluded — they vary run to run with
/// or without tracing.
fn gs_key(o: &gauss_seidel::GsOutcome) -> (u64, u64, u64, u64, u64, u64) {
    (
        o.checksum.to_bits(),
        o.residual.to_bits(),
        o.vtime_ns,
        o.stats.vtime_ns,
        o.stats.tasks,
        o.stats.pauses,
    )
}

#[test]
fn gs_results_bit_identical_tracing_on_vs_off() {
    for version in [GsVersion::InteropBlk, GsVersion::InteropNonBlk] {
        let plain = gauss_seidel::run(&gs_params(version, None)).unwrap();
        let sink = SpanSink::new(1 << 20);
        let traced = gauss_seidel::run(&gs_params(version, Some(sink.clone()))).unwrap();
        assert_eq!(
            gs_key(&plain),
            gs_key(&traced),
            "{}: attaching a span sink changed the results",
            version.name()
        );
        assert!(!sink.snapshot().is_empty(), "{}: no spans recorded", version.name());
    }
}

#[test]
fn ifsker_results_bit_identical_tracing_on_vs_off() {
    for version in [IfsVersion::InteropBlk, IfsVersion::InteropNonBlk] {
        let plain = ifsker::run(&ifs_params(version, None)).unwrap();
        let sink = SpanSink::new(1 << 20);
        let traced = ifsker::run(&ifs_params(version, Some(sink.clone()))).unwrap();
        assert_eq!(
            (
                plain.checksum.to_bits(),
                plain.residual.to_bits(),
                plain.vtime_ns,
                plain.stats.tasks,
                plain.stats.pauses,
            ),
            (
                traced.checksum.to_bits(),
                traced.residual.to_bits(),
                traced.vtime_ns,
                traced.stats.tasks,
                traced.stats.pauses,
            ),
            "{}: attaching a span sink changed the results",
            version.name()
        );
        assert!(!sink.snapshot().is_empty(), "{}: no spans recorded", version.name());
    }
}

#[test]
fn traced_gs_run_emits_every_instrumented_span_kind() {
    let sink = SpanSink::new(1 << 20);
    gauss_seidel::run(&gs_params(GsVersion::InteropBlk, Some(sink.clone()))).unwrap();
    assert_eq!(sink.dropped(), 0, "ring overflowed; grow the test sink");
    let snap = sink.snapshot();
    let kinds: BTreeSet<SpanKind> = snap.iter().map(|s| s.kind).collect();
    for kind in [
        SpanKind::TaskExec,  // worker task execution
        SpanKind::TaskPause, // blocking recv pauses the task (Section 4)
        SpanKind::MpiCall,   // in-task window of the intercepted call
        SpanKind::MpiReq,    // post -> completion request lifetime
        SpanKind::Send,      // message producer endpoint
        SpanKind::Deliver,   // message consumer endpoint
        SpanKind::CollRound, // residual allreduce schedule rounds
        SpanKind::PortBusy,  // rx_ns = 200 puts service time on ports
        SpanKind::LaneWait,  // 2 clock lanes stall on each other's bound
    ] {
        assert!(
            kinds.contains(&kind),
            "no {kind:?} span in the traced run (got {kinds:?})"
        );
    }
    // Snapshot is merge-sorted by time.
    assert!(snap.windows(2).all(|w| w[0].t0 <= w[1].t0), "snapshot not time-sorted");
}

#[test]
fn flows_link_sends_to_cross_rank_deliveries() {
    let sink = SpanSink::new(1 << 20);
    gauss_seidel::run(&gs_params(GsVersion::InteropNonBlk, Some(sink.clone()))).unwrap();
    let snap = sink.snapshot();
    let cross = snap.iter().any(|send| {
        send.kind == SpanKind::Send
            && send.flow_out != 0
            && snap.iter().any(|del| {
                del.kind == SpanKind::Deliver
                    && del.flow_in == send.flow_out
                    && del.track.rank() != send.track.rank()
            })
    });
    assert!(cross, "no send -> deliver flow pair crossing ranks");
}

#[test]
fn perfetto_export_of_real_run_is_structurally_sound() {
    let sink = SpanSink::new(1 << 20);
    gauss_seidel::run(&gs_params(GsVersion::InteropBlk, Some(sink.clone()))).unwrap();
    let json = perfetto::export(&sink.snapshot(), sink.dropped());
    for needle in [
        "\"traceEvents\"",
        "\"dropped_spans\":0",
        "\"ph\":\"M\"", // track metadata
        "\"ph\":\"X\"", // interval spans
        "\"ph\":\"b\"", // async request lifetimes
        "\"ph\":\"e\"",
        "\"ph\":\"s\"", // flow arrows
        "\"ph\":\"f\"",
        "\"cat\":\"task\"",
        "\"cat\":\"lane\"",
        "\"sim clock\"",
        "\"ingress port\"",
    ] {
        assert!(json.contains(needle), "export missing {needle}");
    }
    // Note: the export is NOT asserted byte-identical across runs —
    // steal and lane-wait spans record host-scheduling accidents (in
    // virtual timestamps, but whether they happen at all varies). The
    // deterministic quantities are pinned by the bit-identity tests.
}

#[test]
fn overlap_profiler_ranks_nonblocking_above_blocking() {
    // fig20's core claim at test scale: TAMPI iallreduce hides more
    // communication under compute than in-task blocking allreduce.
    let frac_of = |version| {
        let sink = SpanSink::new(1 << 20);
        let mut p = gs_params(version, Some(sink.clone()));
        p.compute = Compute::Model; // timing only; checksums not needed
        gauss_seidel::run(&p).unwrap();
        let per = overlap::overlap_by_rank(&sink.snapshot());
        overlap::overlap_summary(&per).overlap_frac()
    };
    let blk = frac_of(GsVersion::InteropBlk);
    let nblk = frac_of(GsVersion::InteropNonBlk);
    assert!(
        nblk > blk,
        "non-blocking overlap {nblk:.3} not above blocking {blk:.3}"
    );
}

#[test]
fn metrics_registry_rides_run_stats() {
    // Traced run: the span counter moves and the virtual-time
    // histograms fill.
    let sink = SpanSink::new(1 << 20);
    let traced = gauss_seidel::run(&gs_params(GsVersion::InteropBlk, Some(sink))).unwrap();
    let m = &traced.stats.metrics;
    assert!(m.counters["spans_recorded"] > 0);
    assert!(m.hists["pause_ns"].count > 0, "blocking recvs must pause tasks");
    assert!(m.hists["port_queue_ns"].count > 0, "rx_ns = 200 must queue messages");
    assert!(m.hists["completion_latency_ns"].count > 0);
    assert!(m.gauges.contains_key("port_backlog"));

    // Untraced run: metrics still populate (they are always-on); only
    // the span counter stays at zero.
    let plain = gauss_seidel::run(&gs_params(GsVersion::InteropBlk, None)).unwrap();
    let m = &plain.stats.metrics;
    assert_eq!(m.counters["spans_recorded"], 0);
    assert!(m.hists["pause_ns"].count > 0);
    assert_eq!(
        m.hists["pause_ns"],
        traced.stats.metrics.hists["pause_ns"],
        "virtual-time metrics must be identical tracing on vs off"
    );
}
