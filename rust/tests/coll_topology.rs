//! Topology-aware hierarchical collective schedules + persistent
//! schedule cache (rmpi::topology): flat-vs-hierarchical bit-identity
//! across delivery and wait modes, per-topology round-count formulas,
//! cache hit/miss accounting and comm-drop invalidation,
//! hierarchical-not-slower in virtual time, the collective stall
//! diagnostic, and the `repro figures` unknown-figure exit code.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use tampi_repro::bench;
use tampi_repro::progress::DeliveryMode;
use tampi_repro::rmpi::{ClusterConfig, ThreadLevel, TopologyMode, Universe};
use tampi_repro::sim::ms;
use tampi_repro::tampi;
use tampi_repro::trace::{stall_report, Tracer};

/// Run all six collectives and digest every data result into a bit
/// vector on rank 0. `style`: "park" = blocking calls on the rank main,
/// "taskaware" = TAMPI-intercepted calls inside tasks.
fn collective_digest(
    nodes: usize,
    rpn: usize,
    topo: TopologyMode,
    delivery: DeliveryMode,
    style: &'static str,
) -> Vec<u64> {
    // One slot per recording rank: ranks finish in nondeterministic
    // real-time order, so a flat push would scramble the digest.
    let digest: Arc<Mutex<[Vec<u64>; 2]>> = Arc::new(Mutex::new([Vec::new(), Vec::new()]));
    let d2 = digest.clone();
    let cores = if style == "taskaware" { 1 } else { 0 };
    let mut cfg = ClusterConfig::new(nodes, rpn, cores)
        .with_topology(topo)
        .with_delivery_mode(delivery);
    cfg.deadline = Some(ms(600_000));
    Universe::run(cfg, move |ctx| {
        let n = ctx.size;
        let r = ctx.rank;
        let comm = ctx.comm.clone();

        // The six collectives, with data patterns that expose any
        // misrouting: every element value encodes its origin.
        let bcast_src: Vec<f64> = (0..4).map(|i| 1.25 * (i + 3) as f64).collect();
        let mut bcast_buf = if r == 1 { bcast_src.clone() } else { vec![0.0; 4] };
        let mut reduce_buf = [(r as f64 + 0.5) * 1.125, r as f64 * 0.75];
        let mut allred_buf = [(r as f64 + 1.0) * 0.375];
        let gather_mine = [r as u64 * 1000 + 7];
        let mut gather_all = vec![0u64; n];
        let a2a_send: Vec<u32> = (0..n).map(|d| (r * 1000 + d) as u32).collect();
        let mut a2a_recv = vec![0u32; n];

        match style {
            "park" => {
                comm.barrier();
                comm.bcast(&mut bcast_buf, 1);
                comm.reduce(&mut reduce_buf, 0, |a, b| {
                    a[0] += b[0];
                    a[1] += b[1];
                });
                comm.allreduce(&mut allred_buf, |a, b| a[0] += b[0]);
                if r == 1 {
                    comm.gather(&gather_mine, Some(&mut gather_all), 1);
                } else {
                    comm.gather(&gather_mine, None, 1);
                }
                comm.alltoall(&a2a_send, &mut a2a_recv);
            }
            _ => {
                let rt = ctx.rt.as_ref().unwrap();
                let tm = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
                // One task per collective; each taskwait makes the
                // buffers safe to reuse / read on the rank main.
                let run_in_task = |body: Box<dyn FnOnce() + Send>| {
                    rt.task().label("coll").spawn(body);
                    rt.taskwait();
                };
                {
                    let tm = tm.clone();
                    run_in_task(Box::new(move || tm.barrier()));
                }
                {
                    let tm = tm.clone();
                    let buf: Arc<Mutex<Vec<f64>>> =
                        Arc::new(Mutex::new(std::mem::take(&mut bcast_buf)));
                    let b2 = buf.clone();
                    run_in_task(Box::new(move || {
                        tm.ibcast(&mut b2.lock().unwrap()[..], 1);
                    }));
                    bcast_buf = std::mem::take(&mut *buf.lock().unwrap());
                }
                {
                    let tm = tm.clone();
                    let out = Arc::new(Mutex::new(reduce_buf));
                    let o2 = out.clone();
                    run_in_task(Box::new(move || {
                        // Copy out / copy back: the task pauses inside
                        // the wait, so no lock is held across it.
                        let mut v = *o2.lock().unwrap();
                        tm.comm().reduce_with(
                            &mut v,
                            0,
                            |a, b| {
                                a[0] += b[0];
                                a[1] += b[1];
                            },
                            tampi_repro::rmpi::collectives::WaitMode::TaskAware(None),
                        );
                        *o2.lock().unwrap() = v;
                    }));
                    reduce_buf = *out.lock().unwrap();
                }
                {
                    let tm = tm.clone();
                    let out = Arc::new(Mutex::new(allred_buf));
                    let o2 = out.clone();
                    run_in_task(Box::new(move || {
                        let mut v = *o2.lock().unwrap();
                        tm.allreduce(&mut v, |a, b| a[0] += b[0]);
                        *o2.lock().unwrap() = v;
                    }));
                    allred_buf = *out.lock().unwrap();
                }
                {
                    let tm = tm.clone();
                    let all: Arc<Mutex<Vec<u64>>> =
                        Arc::new(Mutex::new(std::mem::take(&mut gather_all)));
                    let a2 = all.clone();
                    run_in_task(Box::new(move || {
                        if r == 1 {
                            tm.igather(&gather_mine, Some(&mut a2.lock().unwrap()[..]), 1);
                        } else {
                            tm.igather(&gather_mine, None, 1);
                        }
                    }));
                    gather_all = std::mem::take(&mut *all.lock().unwrap());
                }
                {
                    let tm = tm.clone();
                    let send = a2a_send.clone();
                    let recv: Arc<Mutex<Vec<u32>>> =
                        Arc::new(Mutex::new(std::mem::take(&mut a2a_recv)));
                    let r2 = recv.clone();
                    run_in_task(Box::new(move || {
                        tm.ialltoall(&send, &mut r2.lock().unwrap()[..]);
                    }));
                    a2a_recv = std::mem::take(&mut *recv.lock().unwrap());
                }
            }
        }

        // Every rank checks placement-sensitive results...
        assert_eq!(bcast_buf, bcast_src, "bcast payload on rank {r}");
        for (s, &v) in a2a_recv.iter().enumerate() {
            assert_eq!(v, (s * 1000 + r) as u32, "alltoall slot {s} on rank {r}");
        }
        // ...and rank 0/1 record the bit-exact digests.
        let mut bits = Vec::new();
        if r == 0 {
            bits.extend(reduce_buf.iter().map(|v| v.to_bits()));
        }
        bits.push(allred_buf[0].to_bits());
        if r == 1 {
            for &g in &gather_all {
                bits.push(g);
            }
        }
        for &v in &a2a_recv {
            bits.push(v as u64);
        }
        if r <= 1 {
            d2.lock().unwrap()[r] = bits;
        }
    })
    .unwrap();
    let slots = digest.lock().unwrap();
    let out: Vec<u64> = slots.iter().flatten().copied().collect();
    assert!(!out.is_empty());
    out
}

/// Acceptance criterion: all six collectives produce bit-identical
/// results flat vs hierarchical, across {Park, TaskAware} x
/// {Direct, Sharded} — on a power-of-two and a non-power-of-two
/// ranks-per-node shape.
#[test]
fn flat_vs_hierarchical_bitidentical_all_six() {
    for (nodes, rpn) in [(2usize, 4usize), (2, 3)] {
        let reference =
            collective_digest(nodes, rpn, TopologyMode::Flat, DeliveryMode::Direct, "park");
        for topo in [TopologyMode::Flat, TopologyMode::Hierarchical] {
            for delivery in [DeliveryMode::Direct, DeliveryMode::Sharded] {
                for style in ["park", "taskaware"] {
                    let got = collective_digest(nodes, rpn, topo, delivery, style);
                    assert_eq!(
                        got, reference,
                        "digest diverged: {nodes}x{rpn} {topo:?}/{delivery:?}/{style}"
                    );
                }
            }
        }
    }
}

/// Round-count formulas of the hierarchical plans at 4 nodes x 4 ranks
/// (latency regime: barrier/bcast stage through leaders, reduce keeps
/// the flat binomial tree — the combine-order contract).
#[test]
fn round_count_formulas_hierarchical_latency_regime() {
    let (nodes, rpn) = (4usize, 4usize);
    let cfg = ClusterConfig::new(nodes, rpn, 0).with_topology(TopologyMode::Hierarchical);
    Universe::run(cfg, move |ctx| {
        let r = ctx.rank;
        let leader = r % rpn == 0;

        // Barrier: member = 1 round (token out, release in); leader =
        // check-in + log2(nodes) dissemination + release.
        let cr = ctx.comm.ibarrier();
        let want = if leader { 1 + 2 + 1 } else { 1 };
        assert_eq!(cr.rounds_total(), want, "rank {r} barrier rounds");
        cr.wait();

        // Bcast (root 1, deliberately not node-aligned): root 1 round,
        // everyone else recv + forward = 2, in both topologies.
        let mut b = [if r == 1 { 42u64 } else { 0 }];
        let cr = ctx.comm.ibcast(&mut b, 1);
        assert_eq!(cr.rounds_total(), if r == 1 { 1 } else { 2 }, "rank {r} bcast");
        cr.wait();
        assert_eq!(b[0], 42);

        // Reduce keeps the flat binomial shape: interior ranks (even
        // virtual rank) 2 rounds, leaves 1 — identical to Flat mode.
        let mut v = [r as u64];
        let cr = ctx.comm.ireduce(&mut v, 0, |a, b| a[0] += b[0]);
        let interior = r % 2 == 0;
        assert_eq!(cr.rounds_total(), if interior { 2 } else { 1 }, "rank {r} reduce");
        cr.wait();
        if r == 0 {
            assert_eq!(v[0], (0..16u64).sum::<u64>());
        }
    })
    .unwrap();
}

/// Round-count formulas of the staged gather/alltoall plans in the
/// message-rate regime (rx_ns > 0 makes ingress-port fan-in expensive,
/// so the compiler picks leader staging).
#[test]
fn round_count_formulas_staged_message_rate_regime() {
    let (nodes, rpn) = (4usize, 4usize);
    let mut cfg = ClusterConfig::new(nodes, rpn, 0).with_topology(TopologyMode::Hierarchical);
    cfg.net.rx_ns = 400;
    Universe::run(cfg, move |ctx| {
        let r = ctx.rank;
        let n = ctx.size;

        // Gather to root 0: root 1 round; members of the root's node
        // and staging-node members 1; staging leaders 2.
        let mine = [r as u64];
        let cr = if r == 0 {
            let mut all = vec![0u64; n];
            let cr = ctx.comm.igather(&mine, Some(&mut all), 0);
            cr.wait();
            assert_eq!(all, (0..n as u64).collect::<Vec<_>>());
            cr
        } else {
            let cr = ctx.comm.igather(&mine, None, 0);
            cr.wait();
            cr
        };
        let staging_leader = r % rpn == 0 && r != 0;
        assert_eq!(
            cr.rounds_total(),
            if staging_leader { 2 } else { 1 },
            "rank {r} gather rounds"
        );

        // Alltoall: leaders run the 3-phase staged plan, members 1
        // round (ship up, receive down).
        let send: Vec<u32> = (0..n).map(|d| (r * 100 + d) as u32).collect();
        let mut recv = vec![0u32; n];
        let cr = ctx.comm.ialltoall(&send, &mut recv);
        cr.wait();
        let leader = r % rpn == 0;
        assert_eq!(cr.rounds_total(), if leader { 3 } else { 1 }, "rank {r} alltoall");
        for (s, &v) in recv.iter().enumerate() {
            assert_eq!(v, (s * 100 + r) as u32);
        }
    })
    .unwrap();
}

/// Persistent-schedule acceptance: repeated same-shape collectives hit
/// the cache on every call after the first (`hits >= calls - 1` per
/// rank), and a new shape misses once.
#[test]
fn sched_cache_hits_after_first_call() {
    let n = 2usize;
    let calls = 5usize;
    let stats = Universe::run(ClusterConfig::new(n, 1, 0), move |ctx| {
        for i in 0..calls {
            let mut v = [ctx.rank as f64 + i as f64];
            let cr = ctx.comm.iallreduce(&mut v, |a, b| a[0] += b[0]);
            cr.wait();
        }
        // A different shape compiles its own plan.
        let mut w = [0.0f64, 1.0];
        ctx.comm.allreduce(&mut w, |a, b| {
            a[0] += b[0];
            a[1] += b[1];
        });
        assert_eq!(ctx.comm.sched_cache_len(), 2, "two shapes cached");
    })
    .unwrap();
    assert_eq!(stats.sched_cache.misses, 2 * n as u64, "one compile per shape per rank");
    assert_eq!(
        stats.sched_cache.hits,
        (n * (calls - 1)) as u64,
        "every repeat must hit"
    );
}

/// Dropping a communicator drops its per-comm plan *index* — a fresh
/// dup starts cold — but the compiled cluster plans live in the
/// universe plan store and are shared by congruent communicators:
/// the dup's index misses resolve from the store without recompiling
/// and without growing `sched_cache.misses` (each rank already paid
/// its one first-touch compile on the first communicator).
#[test]
fn dup_shares_cluster_plans_across_comm_drop() {
    let n = 2usize;
    let stats = Universe::run(ClusterConfig::new(n, 1, 0), move |ctx| {
        let d1 = ctx.comm.dup();
        let mut v = [ctx.rank as f64 + 0.5];
        d1.allreduce(&mut v, |a, b| a[0] += b[0]);
        d1.allreduce(&mut v, |a, b| a[0] += b[0]);
        assert_eq!(d1.sched_cache_len(), 1);
        drop(d1); // the per-comm index dies with the communicator
        let d2 = ctx.comm.dup();
        assert_eq!(d2.sched_cache_len(), 0, "a fresh dup starts cold");
        d2.allreduce(&mut v, |a, b| a[0] += b[0]);
        assert_eq!(d2.sched_cache_len(), 1);
    })
    .unwrap();
    // Per rank: dup1's first call is the rank's first touch of the
    // cluster plan (one miss), its second call hits the index, and
    // dup2's call re-views the already-touched plan (a hit, not a
    // recompile) — misses must NOT grow on a congruent dup.
    assert_eq!(stats.sched_cache.misses, n as u64);
    assert_eq!(stats.sched_cache.hits, 2 * n as u64);
    // Store-level accounting: the cluster plan compiled exactly once;
    // every other store lookup (one per index miss) found it ready.
    assert_eq!(stats.plan_store.misses, 1);
    assert_eq!(stats.plan_store.hits, 2 * n as u64 - 1);
}

/// Tentpole acceptance: cold-communicator compile work is O(1) compiles
/// per `SchedKey` cluster-wide — n ranks calling the same collective
/// produce exactly one cluster-plan compile through the store, and a
/// second shape compiles exactly once more.
#[test]
fn plan_store_compiles_once_cluster_wide() {
    let n = 4usize;
    let stats = Universe::run(ClusterConfig::new(2, 2, 0), move |ctx| {
        let mut v = [ctx.rank as f64];
        ctx.comm.allreduce(&mut v, |a, b| a[0] += b[0]);
        // A different shape (two elements) is a distinct SchedKey.
        let mut w = [0.0f64, 1.0];
        ctx.comm.allreduce(&mut w, |a, b| {
            a[0] += b[0];
            a[1] += b[1];
        });
    })
    .unwrap();
    // One compile per distinct key, no matter how many ranks ask.
    assert_eq!(stats.plan_store.misses, 2, "O(1) compiles per SchedKey");
    assert_eq!(stats.plan_store.hits, 2 * (n as u64 - 1));
    // Per-rank accounting is unchanged by the shared store: every rank
    // still counts one first-touch miss per key.
    assert_eq!(stats.sched_cache.misses, 2 * n as u64);
}

/// The cost-driven compiler may never lose to flat: in both the pure
/// latency regime (rx = 0) and the message-rate regime (rx = 400),
/// hierarchical virtual time <= flat for every collective at
/// ranks_per_node > 1.
#[test]
fn hierarchical_not_slower_at_rpn_gt_1() {
    for rx in [0u64, 400] {
        for (nodes, rpn) in [(4usize, 2usize), (4, 4)] {
            for kind in bench::COLL_TOPOLOGY_KINDS {
                let flat =
                    bench::coll_topology_vtime(kind, nodes, rpn, 1, TopologyMode::Flat, rx);
                let hier = bench::coll_topology_vtime(
                    kind,
                    nodes,
                    rpn,
                    1,
                    TopologyMode::Hierarchical,
                    rx,
                );
                assert!(
                    hier <= flat,
                    "{kind} hierarchical slower at {nodes}x{rpn} rx={rx}: \
                     hier={hier} ns vs flat={flat} ns"
                );
            }
        }
    }
}

/// The staged plans must actually win where the model says they do: at
/// 4x4 with per-message receiver cost, gather/alltoall/barrier are
/// strictly faster hierarchical.
#[test]
fn hierarchical_wins_in_message_rate_regime() {
    for kind in ["barrier", "gather", "alltoall"] {
        let flat = bench::coll_topology_vtime(kind, 4, 4, 1, TopologyMode::Flat, 400);
        let hier =
            bench::coll_topology_vtime(kind, 4, 4, 1, TopologyMode::Hierarchical, 400);
        assert!(
            hier < flat,
            "{kind} must win strictly: hier={hier} ns vs flat={flat} ns"
        );
    }
}

/// fig17's schedule-cache table: cold compiles per call without the
/// cache, one compile + hits with it — and the cache is time-positive.
#[test]
fn fig17_cache_rows_account() {
    let ranks = 4u64; // 2 nodes x 2 ranks
    let calls = 8usize;
    let cold = bench::coll_cache_run(calls, false);
    let warm = bench::coll_cache_run(calls, true);
    assert_eq!(cold.hits, 0);
    assert_eq!(cold.misses, ranks * calls as u64);
    assert_eq!(warm.misses, ranks);
    assert_eq!(warm.hits, ranks * (calls as u64 - 1));
    // Cache off bypasses the plan store entirely (true cold baseline);
    // with it on, the one schedule key compiles once cluster-wide and
    // the other ranks' store lookups hit.
    assert_eq!((cold.plan_store_hits, cold.plan_store_misses), (0, 0));
    assert_eq!(warm.plan_store_misses, 1);
    assert_eq!(warm.plan_store_hits, ranks - 1);
    assert!(
        warm.vtime_us <= cold.vtime_us,
        "cached reuse must not be slower: {} vs {}",
        warm.vtime_us,
        cold.vtime_us
    );
}

/// The stall diagnostic blames the rank that entered late, and reports
/// nothing once the collective completed.
#[test]
fn stall_report_blames_the_skewed_rank() {
    let n = 4usize;
    let skew = ms(20);
    let tracer = Arc::new(Tracer::new());
    let mut cfg = ClusterConfig::new(n, 1, 0);
    cfg.tracer = Some(tracer.clone());
    let entered = Arc::new(AtomicU64::new(0));
    let e2 = entered.clone();
    Universe::run(cfg, move |ctx| {
        if ctx.rank == ctx.size - 1 {
            ctx.clock.sleep(skew);
            e2.store(ctx.clock.now(), Ordering::Release);
        }
        ctx.comm.barrier();
    })
    .unwrap();
    assert!(entered.load(Ordering::Acquire) >= skew);
    let records = tracer.snapshot();

    // Mid-skew: the barrier is in flight and rank n-1 (no records yet)
    // is the laggard, stalled since launch.
    let mid = stall_report(&records, skew / 2, n);
    assert_eq!(mid.len(), 1, "exactly the barrier in flight: {mid:?}");
    assert_eq!(mid[0].kind, "barrier");
    assert_eq!(mid[0].laggard, (n - 1) as u32);
    assert_eq!(mid[0].laggard_round, 0);
    assert_eq!(mid[0].entered, n - 1);
    assert!(mid[0].stalled_ns >= skew / 2, "stalled {} ns", mid[0].stalled_ns);

    // Well after completion: nothing in flight.
    assert!(stall_report(&records, skew * 4, n).is_empty());
}

/// Regression (satellite fix): `repro figures` must exit non-zero with
/// a clear message on an unknown `--fig`, and must reject `--json` for
/// figures without a machine-readable schema.
#[test]
fn repro_figures_unknown_fig_exits_nonzero() {
    let exe = env!("CARGO_BIN_EXE_repro");
    let out = std::process::Command::new(exe)
        .args(["figures", "--fig", "bogus"])
        .output()
        .expect("spawn repro");
    assert_eq!(out.status.code(), Some(2), "unknown figure must exit 2");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown figure"), "stderr: {err}");

    let out = std::process::Command::new(exe)
        .args(["figures", "--fig", "9", "--json", "should_not_exist.json"])
        .output()
        .expect("spawn repro");
    assert_eq!(out.status.code(), Some(2), "--json needs a schema'd figure");
    assert!(!std::path::Path::new("should_not_exist.json").exists());
}

/// The JSON emitters produce the schema scripts/validate_bench.py pins.
#[test]
fn bench_json_shape() {
    let j = bench::fig15_json(bench::Scale::Quick);
    assert!(j.starts_with("{\"schema_version\":1,\"fig\":15,\"scale\":\"quick\""));
    assert!(j.contains("\"series\":\"polling\""));
    assert!(j.contains("\"latency_ns\":"));
    assert!(j.trim_end().ends_with('}'));
}

/// fig21 emits all three compile strategies per shape (its in-harness
/// asserts already pin the replay-event savings).
#[test]
fn fig21_json_shape() {
    let j = bench::fig21_json(bench::Scale::Quick);
    assert!(j.starts_with("{\"schema_version\":1,\"fig\":21,\"scale\":\"quick\""));
    for strategy in ["per-rank", "cluster", "closed-form"] {
        assert!(j.contains(&format!("\"strategy\":\"{strategy}\"")), "missing {strategy}");
    }
    assert!(j.contains("\"replay_events\":"));
    assert!(j.trim_end().ends_with('}'));
}
