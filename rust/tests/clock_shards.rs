//! Determinism contract of the sharded simulation clock: at equal
//! seeds/parameters, a run is bit-identical to itself (seed replay) and
//! to the same run on any lane count (1 vs 2 vs 4 vs finer-than-node)
//! and under either per-lane event-queue implementation (binary heap
//! vs calendar queue). The projection
//! compared here is the deterministic slice of [`RunStats`] — virtual
//! makespan, task/pause counts, schedule-cache traffic, user counters
//! (checksums/residuals travel as counter bits) — plus, for the trace
//! test, the normalized trace record multiset. Host-race-shaped fields
//! (worker counts, steals, delivery/clock batch counters, host wall
//! time) are deliberately excluded: they describe *how fast* the host
//! simulated, never *what* was simulated.

use std::collections::BTreeMap;
use std::sync::Arc;

use tampi_repro::apps::gauss_seidel::{self, GsParams, GsVersion};
use tampi_repro::apps::ifsker::{self, IfsParams, IfsVersion};
use tampi_repro::apps::Compute;
use tampi_repro::rmpi::{ClusterConfig, RunStats, SchedCacheStats, Universe};
use tampi_repro::sim::{ms, ClockQueueKind};
use tampi_repro::trace::{EventKind, Tracer};

/// The deterministic projection of one run's statistics.
#[derive(Debug, PartialEq)]
struct Projection {
    vtime_ns: u64,
    tasks: u64,
    pauses: u64,
    sched_cache: SchedCacheStats,
    counters: BTreeMap<String, u64>,
}

fn project(stats: &RunStats) -> Projection {
    Projection {
        vtime_ns: stats.vtime_ns,
        tasks: stats.tasks,
        pauses: stats.pauses,
        sched_cache: stats.sched_cache,
        counters: stats.counters.iter().map(|(k, &v)| (k.clone(), v)).collect(),
    }
}

fn gs_params(shards: usize) -> GsParams {
    let mut p = GsParams::new(256, 256, 64, 6, 4, 2, GsVersion::InteropNonBlk);
    p.compute = Compute::Model;
    p.residual_every = 2; // exercise the collective engine too
    p.clock_shards = shards;
    p.deadline = Some(ms(600_000));
    p
}

#[test]
fn gs_seed_replay_is_bit_identical() {
    let a = gauss_seidel::run(&gs_params(1)).expect("gs replay run A");
    let b = gauss_seidel::run(&gs_params(1)).expect("gs replay run B");
    assert_eq!(a.checksum.to_bits(), b.checksum.to_bits());
    assert_eq!(a.residual.to_bits(), b.residual.to_bits());
    assert_eq!(project(&a.stats), project(&b.stats));
}

#[test]
fn gs_sharded_matches_single_lane_bit_for_bit() {
    let base = gauss_seidel::run(&gs_params(1)).expect("gs 1-lane run");
    for shards in [2usize, 4] {
        let run = gauss_seidel::run(&gs_params(shards))
            .unwrap_or_else(|e| panic!("gs {shards}-lane run failed: {e}"));
        assert_eq!(
            run.checksum.to_bits(),
            base.checksum.to_bits(),
            "checksum diverged at {shards} lanes"
        );
        assert_eq!(
            run.residual.to_bits(),
            base.residual.to_bits(),
            "residual diverged at {shards} lanes"
        );
        assert_eq!(
            project(&run.stats),
            project(&base.stats),
            "stats projection diverged at {shards} lanes"
        );
        assert!(
            run.stats.cross_shard_events > 0,
            "halo traffic must cross lanes at {shards} lanes"
        );
    }
}

#[test]
fn ifsker_sharded_matches_single_lane_bit_for_bit() {
    let mk = |shards: usize| {
        let mut p = IfsParams::new(4096, 2, 4, 4, 2, IfsVersion::InteropNonBlk);
        p.compute = Compute::Model;
        p.clock_shards = shards;
        p.deadline = Some(ms(600_000));
        p
    };
    let base = ifsker::run(&mk(1)).expect("ifsker 1-lane run");
    for shards in [2usize, 4] {
        let run = ifsker::run(&mk(shards))
            .unwrap_or_else(|e| panic!("ifsker {shards}-lane run failed: {e}"));
        assert_eq!(
            run.checksum.to_bits(),
            base.checksum.to_bits(),
            "checksum diverged at {shards} lanes"
        );
        assert_eq!(
            project(&run.stats),
            project(&base.stats),
            "stats projection diverged at {shards} lanes"
        );
        assert!(run.stats.cross_shard_events > 0, "transpositions must cross lanes");
    }
}

/// Normalized trace: every record projected to its deterministic slice
/// (virtual instant, rank, kind, label, task id — the worker column is
/// a host scheduling artifact) and sorted. [`EventKind::BatchDelivered`]
/// records are skipped: batch shapes are host-race-dependent by design
/// (see `RunStats::delivery_batches`).
fn normalize(records: &[tampi_repro::trace::Record]) -> Vec<(u64, u32, String, String, u64)> {
    let mut v: Vec<_> = records
        .iter()
        .filter(|r| !matches!(r.kind, EventKind::BatchDelivered { .. }))
        .map(|r| (r.t, r.rank, format!("{:?}", r.kind), r.label.clone(), r.task_id))
        .collect();
    v.sort();
    v
}

/// Pure-MPI scenario (no task runtime): skewed rank mains doing halo
/// p2p plus a barrier and an allreduce per step, traced. The trace a
/// sharded clock produces must equal the single-lane one.
fn traced_run(shards: usize) -> (Vec<(u64, u32, String, String, u64)>, u64) {
    let tracer = Arc::new(Tracer::new());
    let mut cfg = ClusterConfig::new(4, 2, 0).with_clock_shards(shards);
    cfg.tracer = Some(tracer.clone());
    cfg.deadline = Some(ms(600_000));
    let stats = Universe::run(cfg, move |ctx| {
        let n = ctx.size;
        for step in 0..3u64 {
            // Deterministic skew so lanes genuinely run apart.
            ctx.clock.sleep(tampi_repro::sim::us(10 * (ctx.rank as u64 + 1)));
            let right = (ctx.rank + 1) % n;
            let left = (ctx.rank + n - 1) % n;
            let tag = step as i32;
            let mut inbox = [0u64];
            let r = ctx.comm.irecv(&mut inbox, left as i32, tag);
            ctx.comm.send(&[ctx.rank as u64 + step], right, tag);
            ctx.comm.wait(&r);
            assert_eq!(inbox[0], left as u64 + step);
            ctx.comm.barrier();
            let mut v = [ctx.rank as f64 + step as f64];
            ctx.comm.allreduce(&mut v, |a, b| a[0] += b[0]);
        }
    })
    .expect("traced scenario");
    (normalize(&tracer.snapshot()), stats.vtime_ns)
}

#[test]
fn trace_sequence_identical_across_lane_counts() {
    let (base_trace, base_vtime) = traced_run(1);
    assert!(!base_trace.is_empty(), "scenario must produce trace records");
    for shards in [2usize, 4] {
        let (trace, vtime) = traced_run(shards);
        assert_eq!(vtime, base_vtime, "vtime diverged at {shards} lanes");
        assert_eq!(trace, base_trace, "trace diverged at {shards} lanes");
    }
    // Seed replay of the traced scenario itself.
    let (again, vtime) = traced_run(1);
    assert_eq!(vtime, base_vtime);
    assert_eq!(again, base_trace);
}

#[test]
fn shard_count_is_clamped_to_ranks() {
    // 2 nodes of one hybrid rank each, 8 requested lanes: the engine
    // clamps to the rank count (finer-than-rank lanes are meaningless),
    // runs, and stays identical.
    let mut a = gs_params(1);
    a.nodes = 2;
    let mut b = gs_params(8);
    b.nodes = 2;
    let ra = gauss_seidel::run(&a).expect("2-node 1-lane run");
    let rb = gauss_seidel::run(&b).expect("2-node clamped-lane run");
    assert_eq!(ra.checksum.to_bits(), rb.checksum.to_bits());
    assert_eq!(project(&ra.stats), project(&rb.stats));
}

// -------------------------------------------------------------------
// {queue impl} x {lane count} matrices: the calendar queue and the
// finer-than-node lanes must reproduce the (heap, 1 lane) baseline
// bit for bit on gs, ifsker, and a faults-injected recovery run.
// -------------------------------------------------------------------

const QUEUES: [ClockQueueKind; 2] = [ClockQueueKind::BinaryHeap, ClockQueueKind::Calendar];

#[test]
fn gs_queue_lane_matrix_is_bit_identical() {
    let mk = |queue: ClockQueueKind, shards: usize| {
        let mut p = gs_params(shards);
        p.clock_queue = queue;
        gauss_seidel::run(&p).unwrap_or_else(|e| {
            panic!("gs run failed at {}/{shards} lanes: {e}", queue.label())
        })
    };
    let base = mk(ClockQueueKind::BinaryHeap, 1);
    for queue in QUEUES {
        // gs is hybrid (one rank per node, 4 nodes): 8 requested lanes
        // clamp to the rank count and must still be identical.
        for shards in [1usize, 2, 4, 8] {
            let run = mk(queue, shards);
            let cfg = format!("{}/{shards}", queue.label());
            assert_eq!(run.checksum.to_bits(), base.checksum.to_bits(), "checksum at {cfg}");
            assert_eq!(run.residual.to_bits(), base.residual.to_bits(), "residual at {cfg}");
            assert_eq!(project(&run.stats), project(&base.stats), "projection at {cfg}");
        }
    }
}

#[test]
fn ifsker_queue_lane_matrix_is_bit_identical() {
    let mk = |queue: ClockQueueKind, shards: usize| {
        // 4 nodes x 2 ranks/node: 8 lanes run finer than the node
        // blocks, legal under the per-lane-pair lookahead matrix.
        let mut p = IfsParams::new(4096, 2, 4, 4, 2, IfsVersion::InteropNonBlk);
        p.compute = Compute::Model;
        p.clock_shards = shards;
        p.clock_queue = queue;
        p.deadline = Some(ms(600_000));
        ifsker::run(&p).unwrap_or_else(|e| {
            panic!("ifsker run failed at {}/{shards} lanes: {e}", queue.label())
        })
    };
    let base = mk(ClockQueueKind::BinaryHeap, 1);
    for queue in QUEUES {
        for shards in [1usize, 2, 4, 8] {
            let run = mk(queue, shards);
            let cfg = format!("{}/{shards}", queue.label());
            assert_eq!(run.checksum.to_bits(), base.checksum.to_bits(), "checksum at {cfg}");
            assert_eq!(project(&run.stats), project(&base.stats), "projection at {cfg}");
            if shards > 1 {
                assert!(
                    run.stats.cross_shard_events > 0,
                    "transpositions must cross lanes at {cfg}"
                );
            }
        }
    }
}

#[test]
fn faults_inject_queue_lane_matrix_is_bit_identical() {
    use tampi_repro::apps::recovery::{run_gs_shrink, GsShrinkParams, ShrinkParams};
    use tampi_repro::rmpi::FaultsConfig;

    let outcome = |queue: ClockQueueKind, shards: usize| {
        let mut b = ShrinkParams::new(4, 1, 2, 6);
        b.clock_shards = shards;
        b.clock_queue = queue;
        b.deadline = Some(ms(60_000));
        b.faults = Some(FaultsConfig::new(42).with_rank_fail(1, 20_000));
        run_gs_shrink(&GsShrinkParams::new(b, 24, 64)).unwrap_or_else(|e| {
            panic!("gs shrink failed at {}/{shards} lanes: {e}", queue.label())
        })
    };
    let base = outcome(ClockQueueKind::BinaryHeap, 1);
    assert_eq!(base.survivors, 3, "one of four ranks died");
    for queue in QUEUES {
        for shards in [1usize, 2, 4, 8] {
            let run = outcome(queue, shards);
            let cfg = format!("{}/{shards}", queue.label());
            assert_eq!(run.survivors, base.survivors, "survivors at {cfg}");
            assert_eq!(run.vtime_ns, base.vtime_ns, "vtime at {cfg}");
            assert_eq!(run.checksum.to_bits(), base.checksum.to_bits(), "checksum at {cfg}");
        }
    }
}

/// Same-instant cross-lane storm: every rank fires a message at rank 0
/// at the *same* virtual instant, every step, with a serializing
/// ingress port (`rx_ns > 0`) so the `(at, seq)` tie-break order of the
/// simultaneous cross-lane arrivals is observable in downstream
/// completion times. The normalized trace and the virtual makespan must
/// be identical across every {queue impl} x {lane count} configuration
/// — including lanes finer than the node blocks.
fn storm_run(
    shards: usize,
    queue: ClockQueueKind,
) -> (Vec<(u64, u32, String, String, u64)>, u64) {
    let tracer = Arc::new(Tracer::new());
    let mut cfg = ClusterConfig::new(4, 2, 0)
        .with_clock_shards(shards)
        .with_clock_queue(queue);
    cfg.net.rx_ns = 500;
    cfg.tracer = Some(tracer.clone());
    cfg.deadline = Some(ms(600_000));
    let stats = Universe::run(cfg, move |ctx| {
        let n = ctx.size;
        for step in 0..3u64 {
            let tag = step as i32;
            if ctx.rank == 0 {
                for src in 1..n {
                    let mut inbox = [0u64];
                    let r = ctx.comm.irecv(&mut inbox, src as i32, tag);
                    ctx.comm.wait(&r);
                    assert_eq!(inbox[0], src as u64 + step);
                }
            } else {
                // No skew: all sends of a step leave at one instant.
                ctx.comm.send(&[ctx.rank as u64 + step], 0, tag);
            }
            ctx.comm.barrier();
        }
    })
    .expect("storm scenario");
    (normalize(&tracer.snapshot()), stats.vtime_ns)
}

#[test]
fn same_instant_storm_is_queue_and_lane_invariant() {
    let (base_trace, base_vtime) = storm_run(1, ClockQueueKind::BinaryHeap);
    assert!(!base_trace.is_empty(), "storm must produce trace records");
    for queue in QUEUES {
        for shards in [1usize, 2, 4, 8] {
            let (trace, vtime) = storm_run(shards, queue);
            let cfg = format!("{}/{shards}", queue.label());
            assert_eq!(vtime, base_vtime, "vtime diverged at {cfg}");
            assert_eq!(trace, base_trace, "trace diverged at {cfg}");
        }
    }
}
