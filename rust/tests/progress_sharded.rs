//! Sharded progress engine: per-rank completion shards, same-instant
//! batched waves, bulk resume enqueues, and per-worker ready queues with
//! stealing (see `src/progress/`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use tampi_repro::apps::gauss_seidel::{self, GsParams, GsVersion};
use tampi_repro::bench;
use tampi_repro::nanos::{self, runtime::RuntimeCosts};
use tampi_repro::progress::DeliveryMode;
use tampi_repro::rmpi::collectives::WaitMode;
use tampi_repro::rmpi::{ClusterConfig, ThreadLevel, Universe, ANY_SOURCE};
use tampi_repro::sim::{ms, us};
use tampi_repro::tampi;
use tampi_repro::trace::{EventKind, Tracer};

/// A wildcard-source receive is delivered on the shard of the rank that
/// *posted* it, even though the completion is initiated elsewhere (the
/// sender's thread matches it; the clock thread delivers it).
#[test]
fn wildcard_recv_routes_to_poster_shard() {
    let got = Arc::new(AtomicU64::new(0));
    let g2 = got.clone();
    let cfg = ClusterConfig::new(2, 1, 1).with_delivery_mode(DeliveryMode::Sharded);
    Universe::run(cfg, move |ctx| {
        assert_eq!(ctx.comm.delivery_mode(), DeliveryMode::Sharded);
        if ctx.rank == 0 {
            let rt = ctx.rt.as_ref().unwrap();
            let tm = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
            let g = g2.clone();
            rt.task().label("wild").spawn(move || {
                let mut b = [0u64];
                let st = tm.recv(&mut b, ANY_SOURCE, 7);
                assert_eq!(st.source, 1);
                assert_eq!(b[0], 4242);
                g.store(b[0], Ordering::Release);
            });
            rt.taskwait();
            // The continuation was deposited on the poster's shard (rank
            // 0), not on the completing side's (rank 1 stays empty).
            let s0 = ctx.comm.progress_shard_stats(0);
            let s1 = ctx.comm.progress_shard_stats(1);
            assert!(s0.delivered >= 1, "poster shard must deliver: {s0:?}");
            assert_eq!(s0.batches, s0.delivered, "single recv => batches of 1");
            assert_eq!(s1.delivered, 0, "sender shard must stay empty: {s1:?}");
        } else {
            ctx.clock.sleep(ms(2));
            ctx.comm.send(&[4242u64], 0, 7);
        }
    })
    .unwrap();
    assert_eq!(got.load(Ordering::Acquire), 4242);
}

/// A same-instant alltoallv completion wave drains as ONE batch per
/// participating rank's shard — one `BatchDelivered` record of count
/// n-1 (the schedule engine's round continuations), not one per
/// request. The collective's own completion (the task-unblock
/// continuation on the final `CollRequest`, fired by the drain itself)
/// rides a same-instant follow-up batch of 1.
#[test]
fn alltoallv_wave_is_one_batch_per_shard() {
    let n = 4usize;
    let tracer = Arc::new(Tracer::new());
    let mut cfg = ClusterConfig::new(1, n, 1).with_delivery_mode(DeliveryMode::Sharded);
    // Zero modeled costs: every rank posts, sends and pauses at the same
    // virtual instant, so the whole wave completes at one instant too.
    cfg.costs = RuntimeCosts::zero();
    cfg.tracer = Some(tracer.clone());
    let stats = Universe::run(cfg, move |ctx| {
        let rt = ctx.rt.as_ref().unwrap();
        let comm = ctx.comm.clone();
        let size = ctx.size;
        let rank = ctx.rank;
        rt.task().label("a2av").spawn(move || {
            let send: Vec<u32> = (0..size).map(|d| (rank * 100 + d) as u32).collect();
            let mut recv = vec![0u32; size];
            let counts = vec![1usize; size];
            let displs: Vec<usize> = (0..size).collect();
            comm.alltoallv(
                &send,
                &counts,
                &displs,
                &mut recv,
                &counts,
                &displs,
                WaitMode::TaskAware(None),
            );
            for s in 0..size {
                assert_eq!(recv[s], (s * 100 + rank) as u32, "rank {rank} from {s}");
            }
        });
        rt.taskwait();
    })
    .unwrap();

    // Engine totals per rank: the n-1 round continuations of the
    // alltoallv schedule land as one wave batch; the final request's
    // unblock continuation lands as a same-instant follow-up batch.
    assert_eq!(stats.deliveries, (n * n) as u64, "{stats:?}");
    assert_eq!(stats.delivery_batches, (2 * n) as u64, "wave + finish per shard");
    assert_eq!(stats.max_batch, (n - 1) as u64);

    // Trace view: per shard, one BatchDelivered of count n-1 (the wave)
    // followed by one of count 1 (the collective's completion).
    let mut per_shard: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
    for r in tracer.snapshot() {
        if let EventKind::BatchDelivered { shard, count } = r.kind {
            assert_eq!(r.rank, shard);
            per_shard.entry(shard).or_default().push(count);
        }
    }
    assert_eq!(per_shard.len(), n, "every shard must drain: {per_shard:?}");
    for (shard, counts) in &per_shard {
        assert_eq!(
            counts.as_slice(),
            &[(n - 1) as u32, 1],
            "shard {shard}: the wave must land as one batch, not per-request"
        );
    }
}

/// An imbalanced resume/spawn burst lands on one worker's local deque;
/// the other workers serve it by stealing.
#[test]
fn work_stealing_drains_imbalanced_burst() {
    let hits = Arc::new(AtomicU64::new(0));
    let h2 = hits.clone();
    let children = 128u64;
    let cfg = ClusterConfig::new(1, 1, 4);
    let stats = Universe::run(cfg, move |ctx| {
        let rt = ctx.rt.as_ref().unwrap().clone();
        let rt2 = rt.clone();
        let h3 = h2.clone();
        // The spawner runs on ONE worker, so all children enqueue into
        // that worker's local deque; the other three cores can only get
        // work by stealing.
        rt.task().label("spawner").spawn(move || {
            for i in 0..children {
                let h = h3.clone();
                rt2.task().label(format!("burst{i}")).spawn(move || {
                    nanos::work(us(20));
                    h.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        rt.taskwait();
    })
    .unwrap();
    assert_eq!(hits.load(Ordering::Relaxed), children);
    assert!(
        stats.steals > 0,
        "idle workers must steal from the loaded local deque ({stats:?})"
    );
}

/// The acceptance scenario: a same-instant N-request completion wave
/// takes the scheduler lock O(N) times under Direct and O(shards) under
/// Sharded, at identical virtual time (`bench::completion_wave`, also
/// asserted with N=256 in benches/micro_runtime.rs).
#[test]
fn wave_lock_ops_scale_with_shards_not_requests() {
    let n = 32usize;
    let d = bench::completion_wave(n, DeliveryMode::Direct);
    let s = bench::completion_wave(n, DeliveryMode::Sharded);
    assert!(
        d.resume_lock_ops >= n as u64,
        "Direct: one lock acquisition per resume, got {}",
        d.resume_lock_ops
    );
    assert_eq!(d.delivery_batches, 0);
    assert!(
        s.resume_lock_ops <= 4,
        "Sharded: O(shards) lock acquisitions, got {}",
        s.resume_lock_ops
    );
    assert_eq!(s.max_batch, n as u64, "the wave must land as one batch");
    assert!(s.deliveries >= n as u64);
    assert_eq!(
        d.vtime_ns, s.vtime_ns,
        "delivery mode must not change virtual time"
    );
}

/// Direct and Sharded delivery produce bit-identical application results
/// on Gauss-Seidel (both TAMPI interop versions).
#[test]
fn gs_results_identical_across_delivery_modes() {
    for v in [GsVersion::InteropBlk, GsVersion::InteropNonBlk] {
        let run = |delivery: DeliveryMode| {
            let mut p = GsParams::new(256, 256, 64, 6, 2, 2, v);
            p.delivery_mode = delivery;
            gauss_seidel::run(&p).unwrap()
        };
        let a = run(DeliveryMode::Direct);
        let b = run(DeliveryMode::Sharded);
        assert!(a.checksum > 0.0, "{}: heat must flow", v.name());
        assert_eq!(
            a.checksum,
            b.checksum,
            "{}: Direct and Sharded must agree bit-for-bit",
            v.name()
        );
        assert_eq!(a.stats.tasks, b.stats.tasks);
    }
}
