//! Tour of the paper's three runtime APIs (Section 4), used directly:
//! pause/resume, external events, and polling services — without MPI.
//!
//! Run with: `cargo run --release --example runtime_tour`

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex};

use tampi_repro::nanos::{self, Mode, Runtime, RuntimeConfig};
use tampi_repro::sim::{ms, Clock};

fn main() {
    let (clock, clock_handle) = Clock::start();
    clock.set_panic_on_deadlock(false);
    let hold = clock.hold();
    let rt = Runtime::new(clock.clone(), RuntimeConfig::new(2));
    clock.register_thread(); // this thread joins the simulation
    drop(hold);
    rt.attach();

    // --- 1. Pause/resume (Section 4.1) ------------------------------
    println!("1) pause/resume: a task blocks, another unblocks it");
    let parked: Arc<Mutex<Option<nanos::BlockingContext>>> = Arc::new(Mutex::new(None));
    let p2 = parked.clone();
    rt.task().label("sleeper").spawn(move || {
        let ctx = nanos::get_current_blocking_context();
        *p2.lock().unwrap() = Some(ctx.clone());
        println!("   sleeper: pausing at t={} ns", nanos::current_clock().now());
        nanos::block_current_task(&ctx);
        println!("   sleeper: resumed at t={} ns", nanos::current_clock().now());
    });
    let p3 = parked.clone();
    rt.task().label("waker").spawn(move || {
        nanos::work(ms(2)); // simulate useful work on the same cores
        let ctx = p3.lock().unwrap().take().expect("sleeper parked first");
        println!("   waker: unblocking the sleeper");
        nanos::unblock_task(&ctx);
    });
    rt.taskwait();

    // --- 2. External events (Section 4.3) ----------------------------
    println!("2) external events: dependencies release after the event");
    let obj = rt.dep("buffer");
    rt.task().label("producer").dep(&obj, Mode::Out).spawn(|| {
        let ec = nanos::get_current_event_counter();
        nanos::increase_current_task_event_counter(&ec, 1);
        let clock = nanos::current_clock();
        let ec2 = ec.clone();
        // Some external agent fulfils the event 5 ms later:
        clock.call_at(clock.now() + ms(5), move || {
            nanos::decrease_task_event_counter(&ec2, 1);
        });
        println!("   producer: body done at t={} ns (event pending)", clock.now());
    });
    rt.task().label("consumer").dep(&obj, Mode::In).spawn(|| {
        println!(
            "   consumer: running at t={} ns (after the event)",
            nanos::current_clock().now()
        );
    });
    rt.taskwait();

    // --- 3. Polling services (Section 4.2) ---------------------------
    println!("3) polling services: periodic progress callbacks");
    let calls = Arc::new(AtomicU32::new(0));
    let c2 = calls.clone();
    rt.register_polling_service(
        "demo",
        Box::new(move || {
            let n = c2.fetch_add(1, Ordering::Relaxed) + 1;
            n >= 5 // done after five invocations -> auto-unregister
        }),
    );
    rt.task().spawn(|| nanos::work(ms(2)));
    rt.taskwait();
    println!("   service ran {} times, then unregistered itself", calls.load(Ordering::Relaxed));

    rt.detach();
    clock.deregister_thread();
    rt.shutdown();
    clock.stop();
    clock_handle.join().unwrap();
    println!("tour complete at virtual t={} ns", clock.now());
}
