//! IFSKer demo: the Section 7.2 weather-model mock-up on one simulated
//! node, comparing the three versions — and exercising the PJRT spectral
//! kernel on a real chunk as a numerics cross-check.
//!
//! Run with: `cargo run --release --example ifsker`

use tampi_repro::apps::ifsker::{run, IfsParams, IfsVersion};
use tampi_repro::apps::Compute;
use tampi_repro::sim::ms;

fn main() {
    // Real-numerics comparison on a small workload.
    println!("IFSKer 8192 gridpoints, 4 fields, 6 steps, 1 node x 4 ranks:");
    let mut base = None;
    for v in IfsVersion::all() {
        let mut p = IfsParams::new(8192, 4, 6, 1, 4, v);
        p.compute = Compute::Native;
        p.deadline = Some(ms(600_000));
        let out = run(&p).expect(v.name());
        let t = out.vtime_ns as f64 / 1e6;
        let speedup = base.map(|b: f64| b / t).unwrap_or(1.0);
        if base.is_none() {
            base = Some(t);
        }
        println!(
            "  {:<16} vtime {:>9.3} ms | speedup {:>5.2}x | pauses {:>5} | checksum {:.6}",
            v.name(),
            t,
            speedup,
            out.stats.pauses,
            out.checksum
        );
    }

    // Larger, cost-model run showing the single-node gap (Fig 14 shape).
    println!("\nscaled run (cost model, 64K gridpoints, 8 fields, 8 steps, 16 ranks):");
    let mut base = None;
    for v in IfsVersion::all() {
        let mut p = IfsParams::new(64 * 1024, 8, 8, 1, 16, v);
        p.compute = Compute::Model;
        p.deadline = Some(ms(60_000_000));
        let out = run(&p).expect(v.name());
        let t = out.vtime_ns as f64 / 1e6;
        let speedup = base.map(|b: f64| b / t).unwrap_or(1.0);
        if base.is_none() {
            base = Some(t);
        }
        println!(
            "  {:<16} vtime {:>9.3} ms | speedup {:>5.2}x vs pure",
            v.name(),
            t,
            speedup
        );
    }

    // PJRT spectral kernel cross-check (L1/L2/L3 composition). Skipped
    // in stub builds (no `pjrt` feature) even when artifacts exist.
    if tampi_repro::runtime::available("ifs_step_f8_n64") {
        let k = tampi_repro::runtime::IfsKernel::load(8, 64).expect("ifs kernel");
        let fields: Vec<f32> = (0..8 * 64).map(|i| 0.3 + 0.001 * (i % 7) as f32).collect();
        let (out, norm) = k.step(&fields).expect("step");
        println!(
            "\nPJRT spectral kernel: norm {norm:.4}, mean {:.4} (fields stay bounded)",
            out.iter().sum::<f32>() / out.len() as f32
        );
        assert!(norm.is_finite() && norm > 0.0);
    } else {
        println!("\n(artifacts not built; skipping the PJRT spectral check)");
    }
}
