//! End-to-end driver: solve the heat equation on a real (small) workload
//! through ALL THREE LAYERS — Pallas kernel (L1) lowered by JAX (L2) to
//! HLO, executed via PJRT from the Rust coordinator (L3) with TAMPI
//! non-blocking communication tasks on the simulated cluster.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example gauss_seidel
//!
//! Prints per-phase progress, verifies the PJRT result against the native
//! Rust kernel, and reports the paper-style metrics. Recorded in
//! EXPERIMENTS.md §End-to-end.

use std::time::Instant;

use tampi_repro::apps::gauss_seidel::{run, GsParams, GsVersion};
use tampi_repro::apps::Compute;
use tampi_repro::sim::ms;

fn main() {
    let (rows, cols, block, iters) = (512, 512, 128, 40);
    let (nodes, cores) = (2, 2);

    if !tampi_repro::runtime::available(&format!("gs_block_{block}")) {
        eprintln!(
            "PJRT backend unavailable — build with `--features pjrt` (vendored \
             xla/anyhow) and run `make artifacts` first"
        );
        std::process::exit(1);
    }

    println!(
        "heat equation {rows}x{cols}, block {block}, {iters} iterations, \
         {nodes} nodes x {cores} cores, version interop-nonblk"
    );

    // 1) PJRT path: Pallas->HLO kernel executed from compute tasks.
    let mut p = GsParams::new(rows, cols, block, iters, nodes, cores, GsVersion::InteropNonBlk);
    p.compute = Compute::Pjrt;
    p.deadline = Some(ms(600_000));
    let wall = Instant::now();
    let pjrt = run(&p).expect("pjrt run");
    let pjrt_wall = wall.elapsed();
    println!(
        "PJRT   : vtime {:>8.3} ms | {:.3e} cells/s | checksum {:.6} | wall {:.1}s",
        pjrt.vtime_ns as f64 / 1e6,
        pjrt.cells_per_sec(&p),
        pjrt.checksum,
        pjrt_wall.as_secs_f64()
    );

    // 2) Native path: same run with the Rust kernel.
    let mut pn = p.clone();
    pn.compute = Compute::Native;
    let wall = Instant::now();
    let native = run(&pn).expect("native run");
    println!(
        "native : vtime {:>8.3} ms | {:.3e} cells/s | checksum {:.6} | wall {:.1}s",
        native.vtime_ns as f64 / 1e6,
        native.cells_per_sec(&pn),
        native.checksum,
        wall.elapsed().as_secs_f64()
    );

    // 3) Cross-check: the Pallas kernel solves the row recurrence with an
    // associative scan, so agreement is to f32 rounding, not bitwise.
    let rel = (pjrt.checksum - native.checksum).abs() / native.checksum.abs().max(1e-9);
    println!("cross-check: relative checksum error {rel:.3e}");
    assert!(rel < 1e-4, "PJRT and native kernels diverged");

    // 4) Paper-style comparison on the same workload (model compute).
    println!("\nversion comparison (cost-model compute, same workload):");
    for v in GsVersion::all() {
        let mut pv = p.clone();
        pv.version = v;
        pv.compute = Compute::Model;
        match run(&pv) {
            Ok(out) => println!(
                "  {:<16} vtime {:>9.3} ms | pauses {:>5} | workers {:>3}",
                v.name(),
                out.vtime_ns as f64 / 1e6,
                out.stats.pauses,
                out.stats.workers
            ),
            Err(e) => println!("  {:<16} FAILED: {e}", v.name()),
        }
    }
    println!("\nOK: all three layers compose (Pallas -> HLO -> PJRT -> tasks -> TAMPI -> rmpi)");
}
