//! Quickstart: a 2-node simulated cluster where communication tasks use
//! both TAMPI modes — the smallest complete TAMPI program.
//!
//! Run with: `cargo run --release --example quickstart`

use std::sync::{Arc, Mutex};

use tampi_repro::nanos::Mode;
use tampi_repro::rmpi::{ClusterConfig, ThreadLevel, Universe};
use tampi_repro::tampi;

fn main() {
    // 2 nodes x 1 rank x 2 cores, default Omni-Path-like interconnect.
    let cfg = ClusterConfig::new(2, 1, 2);
    let stats = Universe::run(cfg, |ctx| {
        let rt = ctx.rt.as_ref().unwrap();
        // MPI_Init_thread(..., MPI_TASK_MULTIPLE) — Fig 6.
        let tm = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
        assert!(tm.enabled());

        if ctx.rank == 0 {
            // Blocking mode: a task calls plain (task-aware) recv; while
            // the message is in flight the core runs other tasks.
            let tm1 = tm.clone();
            rt.task().label("recv-blocking").spawn(move || {
                let mut buf = [0f64; 4];
                let st = tm1.recv(&mut buf, 1, 7);
                println!("[rank0] blocking-mode recv got {buf:?} from {}", st.source);
            });

            // Non-blocking mode (Fig 5): irecv + TAMPI_Iwait inside a task
            // with an out-dependency; the consumer task runs only when the
            // message really arrived, although the comm task ends at once.
            let buf: Arc<Mutex<[f64; 2]>> = Arc::new(Mutex::new([0.0; 2]));
            let obj = rt.dep("buf");
            let (tm2, b2) = (tm.clone(), buf.clone());
            rt.task()
                .label("recv-nonblocking")
                .dep(&obj, Mode::Out)
                .spawn(move || {
                    let mut g = b2.lock().unwrap();
                    let req = tm2.comm().irecv(&mut *g, 1, 8);
                    drop(g);
                    tm2.iwait(&req); // returns immediately
                });
            rt.task()
                .label("consume")
                .dep(&obj, Mode::In)
                .spawn(move || {
                    let g = buf.lock().unwrap();
                    println!("[rank0] consumer sees {:?} (event-gated)", *g);
                });
        } else {
            ctx.comm.send(&[1.5f64, 2.5, 3.5, 4.5], 0, 7);
            ctx.comm.send(&[41.0f64, 1.0], 0, 8);
        }
    })
    .expect("cluster run");
    println!(
        "done: vtime {:.3} ms, {} tasks, {} pauses, {} workers",
        stats.vtime_ns as f64 / 1e6,
        stats.tasks,
        stats.pauses,
        stats.workers
    );
}
