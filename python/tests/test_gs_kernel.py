"""L1 correctness: Pallas Gauss-Seidel kernel vs the scalar-loop oracle.

Includes a hypothesis sweep over block shapes/values/dtypes, per the
repro requirements (kernel vs ref.py assert_allclose).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gauss_seidel as gs
from compile.kernels import ref
from compile import model


def _rand_case(rng, b, dtype=np.float32, scale=1.0):
    u = (rng.standard_normal((b, b)) * scale).astype(dtype)
    halos = [(rng.standard_normal(b) * scale).astype(dtype) for _ in range(4)]
    return u, halos


def _run_kernel(u, halos):
    args = [jnp.asarray(u)] + [jnp.asarray(h) for h in halos]
    return np.asarray(gs.gs_block(*args))


@pytest.mark.parametrize("b", [2, 3, 8, 16, 33, 64])
def test_gs_matches_reference(b):
    rng = np.random.default_rng(b)
    u, halos = _rand_case(rng, b)
    got = _run_kernel(u, halos)
    want = ref.gs_reference(u, *halos)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_gs_zero_input_zero_output():
    b = 8
    z = np.zeros((b, b), np.float32)
    zh = [np.zeros(b, np.float32)] * 4
    got = _run_kernel(z, zh)
    np.testing.assert_array_equal(got, np.zeros((b, b), np.float32))


def test_gs_constant_field_fixed_point():
    """A constant field with matching halos is a fixed point of the sweep."""
    b = 16
    c = 3.25
    u = np.full((b, b), c, np.float32)
    halos = [np.full(b, c, np.float32)] * 4
    got = _run_kernel(u, halos)
    np.testing.assert_allclose(got, u, rtol=1e-5)


def test_gs_uses_new_top_left_and_old_bottom_right():
    """Directional check: top/left halos act as iteration-t values."""
    b = 4
    u = np.zeros((b, b), np.float32)
    top = np.ones(b, np.float32)
    zeros = np.zeros(b, np.float32)
    got = _run_kernel(u, [top, zeros, zeros, zeros])
    want = ref.gs_reference(u, top, zeros, zeros, zeros)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # first row sees the top halo directly
    assert got[0, 0] == pytest.approx(0.25, rel=1e-5)


def test_gs_sweep_reduces_residual():
    """Repeated sweeps with fixed boundary converge (heat equation)."""
    b = 16
    rng = np.random.default_rng(7)
    u = rng.random((b, b)).astype(np.float32)
    halos = [np.zeros(b, np.float32)] * 4
    prev = np.abs(u).sum()
    cur = u
    for _ in range(100):
        cur = _run_kernel(cur, halos)
    assert np.abs(cur).sum() < 5e-2 * prev


def test_gs_step_delta():
    """L2 gs_step returns the squared-change reduction."""
    b = 8
    rng = np.random.default_rng(3)
    u, halos = _rand_case(rng, b)
    new, delta = jax.jit(model.gs_step)(
        jnp.asarray(u), *[jnp.asarray(h) for h in halos]
    )
    want = ref.gs_reference(u, *halos)
    np.testing.assert_allclose(np.asarray(new), want, rtol=1e-4, atol=1e-5)
    assert float(delta) == pytest.approx(
        float(np.sum((np.asarray(new) - u) ** 2)), rel=1e-4
    )


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([2, 4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_gs_hypothesis_sweep(b, seed, scale):
    rng = np.random.default_rng(seed)
    u, halos = _rand_case(rng, b, scale=scale)
    got = _run_kernel(u, halos)
    want = ref.gs_reference(u, *halos)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-5 * scale)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_gs_row_recurrence_property(seed):
    """Row solver satisfies y[j] = A*y[j-1] + b[j] pointwise."""
    rng = np.random.default_rng(seed)
    n = 32
    prev_new = rng.standard_normal(n).astype(np.float32)
    base = rng.standard_normal(n).astype(np.float32)
    left = np.float32(rng.standard_normal())
    y = np.asarray(
        jax.jit(gs._row_solve)(
            jnp.asarray(prev_new), jnp.asarray(base), jnp.asarray(left)
        )
    )
    b = base + gs.A * prev_new
    yprev = left
    for j in range(n):
        want = gs.A * yprev + b[j]
        assert y[j] == pytest.approx(want, rel=1e-3, abs=1e-5)
        yprev = y[j]
