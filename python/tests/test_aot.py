"""AOT path: lowering produces parseable HLO text with the right signature."""

import re

import jax
import jax.numpy as jnp
import pytest

from compile import aot


@pytest.mark.parametrize("b", [8, 32])
def test_gs_lowering_is_hlo_text(b):
    text = aot.to_hlo_text(aot.lower_gs(b))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # 5 entry params: block + 4 halos (count inside the ENTRY block only)
    entry = text[text.rindex("ENTRY"):]
    assert len(re.findall(r"parameter\(", entry)) == 5
    assert f"f32[{b},{b}]" in text


def test_gs_lowering_returns_tuple():
    text = aot.to_hlo_text(aot.lower_gs(8))
    # return_tuple=True -> root is a tuple of (block, delta)
    assert re.search(r"\(f32\[8,8\]\{?[0-9,]*\}?, f32\[\]\)", text)


def test_ifs_lowering_is_hlo_text():
    text = aot.to_hlo_text(aot.lower_ifs(4, 32))
    assert text.startswith("HloModule")
    assert "f32[4,32]" in text
    # fields + ft + finvt + damp: 4 entry parameters (matrices must be
    # arguments — as_hlo_text elides large constants!)
    entry = text[text.rindex("ENTRY"):]
    assert len(re.findall(r"parameter\(", entry)) == 4


def test_no_elided_constants_anywhere():
    for b in (8, 32):
        assert "constant({...})" not in aot.to_hlo_text(aot.lower_gs(b))
    assert "constant({...})" not in aot.to_hlo_text(aot.lower_ifs(4, 32))


def test_gs_lowering_uses_loop_not_unroll():
    """The row loop must lower to a while loop, not B unrolled bodies."""
    small = aot.to_hlo_text(aot.lower_gs(8))
    big = aot.to_hlo_text(aot.lower_gs(64))
    assert "while" in small
    # HLO size must grow sublinearly with block size (no unrolling).
    assert len(big) < 2 * len(small)
