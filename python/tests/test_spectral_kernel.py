"""L1 correctness: tiled matmul + physics kernels and the IFS step graph."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import spectral, ref
from compile import model


@pytest.mark.parametrize(
    "m,k,n", [(1, 1, 1), (4, 8, 4), (48, 96, 32), (128, 128, 128), (130, 70, 10)]
)
def test_matmul_matches_numpy(m, k, n):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(spectral.matmul(jnp.asarray(a), jnp.asarray(b)))
    want = a.astype(np.float64) @ b.astype(np.float64)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(spectral.matmul(jnp.asarray(a), jnp.asarray(b)))
    want = a.astype(np.float64) @ b.astype(np.float64)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("bm,bn,bk", [(16, 16, 16), (128, 128, 128), (32, 8, 64)])
def test_matmul_tile_invariance(bm, bn, bk):
    """Result must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(5)
    a = rng.standard_normal((64, 64)).astype(np.float32)
    b = rng.standard_normal((64, 64)).astype(np.float32)
    got = np.asarray(spectral.matmul(jnp.asarray(a), jnp.asarray(b), bm=bm, bn=bn, bk=bk))
    want = np.asarray(spectral.matmul(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_physics_matches_reference():
    rng = np.random.default_rng(2)
    u = rng.random((16, 32)).astype(np.float32)
    got = np.asarray(spectral.physics(jnp.asarray(u), dt=0.05))
    np.testing.assert_allclose(got, ref.physics_reference(u), rtol=1e-5)


def test_dft_pair_inverts():
    f, finv = ref.dft_matrices(64)
    eye = finv.astype(np.float64) @ f.astype(np.float64)
    np.testing.assert_allclose(eye, np.eye(64), atol=1e-3)


def test_damping_profile():
    d = ref.spectral_damping(64)
    assert d[0] == 1.0
    assert d[-1] < 0.2
    assert np.all(np.diff(d) <= 1e-7)


@pytest.mark.parametrize("nf,n", [(4, 32), (8, 64)])
def test_ifs_step_matches_reference(nf, n):
    rng = np.random.default_rng(nf + n)
    fields = rng.random((nf, n)).astype(np.float32)
    step = jax.jit(model.make_ifs_step(n))
    got, norm = step(jnp.asarray(fields))
    want = ref.ifs_reference(fields)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-4)
    assert float(norm) == pytest.approx(float(np.sum(np.asarray(got) ** 2)), rel=1e-4)


def test_ifs_step_damps_high_modes():
    """A pure high-frequency field loses energy; smooth field is preserved."""
    n = 64
    step = jax.jit(model.make_ifs_step(n, dt=0.0))
    hi = np.cos(np.pi * np.arange(n)).astype(np.float32)[None, :]  # Nyquist
    lo = np.cos(2 * np.pi * np.arange(n) / n).astype(np.float32)[None, :]
    oh, _ = step(jnp.asarray(hi))
    ol, _ = step(jnp.asarray(lo))
    assert np.sum(np.asarray(oh) ** 2) < 0.1 * np.sum(hi**2)  # e^-4 ~ 0.018
    assert np.sum(np.asarray(ol) ** 2) > 0.95 * np.sum(lo**2)


def test_dft_orthonormal():
    f, finv = ref.dft_matrices(32)
    np.testing.assert_allclose(f @ finv, np.eye(32), atol=1e-5)
    np.testing.assert_allclose(finv, f.T, atol=0)
