"""L1 Pallas kernel: in-block Gauss-Seidel sweep for the 2-D heat equation.

The paper's compute hot-spot (Section 7.1) is the per-block Gauss-Seidel
update

    u_new[i,j] = 0.25 * (u_new[i-1,j] + u_old[i+1,j]
                         + u_new[i,j-1] + u_old[i,j+1])

which is sequential in both spatial dimensions.  The TPU-shaped insight is
that, once the previous *row* of new values is known, the within-row
dependence is a first-order linear recurrence

    y[j] = a * y[j-1] + b[j],      a = 0.25,
    b[j] = 0.25 * (u_new[i-1,j] + u_old[i+1,j] + u_old[i,j+1])

which is solved in O(log B) depth with an associative scan over affine-map
composition.  The outer row loop is a `lax.fori_loop` carrying the previous
new row, so nothing is unrolled and the lowered HLO stays small for any
block size.

Hardware adaptation (DESIGN.md section 3): the whole block plus its four
halo vectors live in one VMEM-resident BlockSpec (a 512x512 f32 block is
1 MiB, far below the ~16 MiB VMEM budget); the scan is VPU work expressed
as vector ops, not a scalar loop.  `interpret=True` is mandatory: the CPU
PJRT plugin cannot execute Mosaic custom-calls (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

A = 0.25  # Jacobi/Gauss-Seidel stencil weight for the 4-point Laplacian.


def _affine_compose(l, r):
    """Compose affine maps (a, b): x -> a*x + b, applied left-then-right."""
    a1, b1 = l
    a2, b2 = r
    return a1 * a2, b1 * a2 + b2


def _row_solve(prev_new, base_row, left_i):
    """Solve y[j] = A*y[j-1] + (base_row[j] + A*prev_new[j]), y[-1]=left_i."""
    b = base_row + A * prev_new
    # Fold the initial condition into b[0]:  y[0] = A*left + b[0].
    b = b.at[0].add(A * left_i)
    a = jnp.full_like(b, A)
    _, y = lax.associative_scan(_affine_compose, (a, b))
    return y


def gs_kernel(u_ref, top_ref, bottom_ref, left_ref, right_ref, o_ref):
    """Pallas kernel body: one full Gauss-Seidel sweep over a (B, B) block.

    Inputs:
      u_ref      (B, B)  block values from the previous iteration
      top_ref    (B,)    NEW values of the row above the block (iteration t)
      bottom_ref (B,)    OLD values of the row below the block (iteration t-1)
      left_ref   (B,)    NEW values of the column left of the block
      right_ref  (B,)    OLD values of the column right of the block
    Output:
      o_ref      (B, B)  updated block (iteration t)
    """
    u = u_ref[...]
    top = top_ref[...]
    bottom = bottom_ref[...]
    left = left_ref[...]
    right = right_ref[...]
    nrows = u.shape[0]

    # Old-value contributions, row-aligned:
    #   below[i, j] = u_old[i+1, j]   (last row -> bottom halo)
    #   rightn[i, j] = u_old[i, j+1]  (last col -> right halo)
    below = jnp.concatenate([u[1:, :], bottom[None, :]], axis=0)
    rightn = jnp.concatenate([u[:, 1:], right[:, None]], axis=1)
    base = A * (below + rightn)

    def body(i, carry):
        prev_new, out = carry
        base_row = lax.dynamic_slice_in_dim(base, i, 1, axis=0)[0]
        left_i = lax.dynamic_slice_in_dim(left, i, 1, axis=0)[0]
        y = _row_solve(prev_new, base_row, left_i)
        out = lax.dynamic_update_slice_in_dim(out, y[None, :], i, axis=0)
        return y, out

    _, out = lax.fori_loop(0, nrows, body, (top, jnp.zeros_like(u)))
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=("block_size",))
def gs_block(u, top, bottom, left, right, *, block_size=None):
    """Run one Gauss-Seidel sweep over a block via the Pallas kernel."""
    b = u.shape[0] if block_size is None else block_size
    assert u.shape == (b, b), (u.shape, b)
    return pl.pallas_call(
        gs_kernel,
        out_shape=jax.ShapeDtypeStruct((b, b), u.dtype),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls.
    )(u, top, bottom, left, right)
