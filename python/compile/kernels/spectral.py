"""L1 Pallas kernels for the IFSKer mock-up (Section 7.2).

IFS represents fields by coefficients of a basis function and alternates
grid-point physics with spectral transforms.  We implement:

  * `physics_kernel`  - element-wise grid-point physics (a logistic
    reaction step), pure VPU work.
  * `matmul_kernel`   - a tiled matrix-multiply used to apply the real DFT
    synthesis/analysis matrices.  This is the MXU-shaped formulation of a
    spectral transform: on real TPU hardware each (bm, bk) x (bk, bn) tile
    maps onto the 128x128 systolic array; here the same BlockSpec schedule
    runs under interpret=True.

The DFT matrices are baked into the lowered HLO as constants by
`model.ifs_step`, so the Rust side only feeds field data.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def physics_kernel(u_ref, o_ref, *, dt):
    """Grid-point physics: logistic reaction u += dt * u * (1 - u)."""
    u = u_ref[...]
    o_ref[...] = u + dt * u * (1.0 - u)


@functools.partial(jax.jit, static_argnames=("dt",))
def physics(u, *, dt=0.05):
    return pl.pallas_call(
        functools.partial(physics_kernel, dt=dt),
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        interpret=True,
    )(u)


def matmul_kernel(a_ref, b_ref, o_ref):
    """Tiled matmul with accumulation over the K grid dimension.

    Grid is (M/bm, N/bn, K/bk); the output tile is revisited for every k
    step, so it is zeroed on the first and accumulated afterwards.
    """

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _tile(n, cap):
    """Largest divisor of n that is <= cap (tile sizes must divide evenly)."""
    t = min(n, cap)
    while n % t:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, *, bm=128, bn=128, bk=128):
    """C = A @ B via the tiled Pallas kernel (shapes need not be multiples
    of 128; tiles shrink to the largest divisor)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = _tile(m, bm), _tile(n, bn), _tile(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
