"""Pure-numpy/jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite checks the kernels against:
  * `gs_reference`  - scalar double-loop Gauss-Seidel sweep.
  * `dft_matrices`  - real DFT analysis/synthesis matrices.
  * `ifs_reference` - physics -> spectral filter -> inverse, via numpy.
"""

import numpy as np

A = 0.25


def gs_reference(u, top, bottom, left, right):
    """Scalar-loop Gauss-Seidel sweep; the literal recurrence from the paper.

    u: (B, B) old block; top/left: NEW halos; bottom/right: OLD halos.
    """
    u = np.asarray(u, dtype=np.float64)
    b = u.shape[0]
    out = np.zeros_like(u)
    for i in range(b):
        for j in range(b):
            up = out[i - 1, j] if i > 0 else float(top[j])
            lf = out[i, j - 1] if j > 0 else float(left[i])
            dn = float(u[i + 1, j]) if i < b - 1 else float(bottom[j])
            rt = float(u[i, j + 1]) if j < b - 1 else float(right[i])
            out[i, j] = A * (up + dn + lf + rt)
    return out


def physics_reference(u, dt=0.05):
    u = np.asarray(u, dtype=np.float64)
    return u + dt * u * (1.0 - u)


def _dft_freqs(n):
    """Per-row frequency index of the orthonormal real Fourier basis."""
    assert n % 2 == 0 and n >= 2, n
    freqs = [0]
    for m in range(1, n // 2):
        freqs += [m, m]
    freqs.append(n // 2)
    return np.asarray(freqs)


def dft_matrices(n, dtype=np.float32):
    """Orthonormal real DFT pair: analysis F (n, n), synthesis Finv = F^T.

    Rows: DC, then (cos_m, sin_m) for m = 1..n/2-1, then the Nyquist
    cosine.  Orthonormal, so the pair is exactly inverse and everything
    stays f32 (the real re-formulation of IFS's spectral transform).
    """
    j = np.arange(n)
    rows = [np.ones(n) / np.sqrt(n)]
    for m in range(1, n // 2):
        ang = 2.0 * np.pi * m * j / n
        rows.append(np.cos(ang) * np.sqrt(2.0 / n))
        rows.append(np.sin(ang) * np.sqrt(2.0 / n))
    rows.append(np.cos(np.pi * j) / np.sqrt(n))
    f = np.stack(rows)
    return f.astype(dtype), f.T.copy().astype(dtype)


def spectral_damping(n, cutoff=0.5, dtype=np.float32):
    """Damping profile applied in spectral space (high modes attenuated)."""
    mode = _dft_freqs(n) / (n // 2)
    damp = np.where(mode <= cutoff, 1.0, np.exp(-4.0 * (mode - cutoff)))
    return damp.astype(dtype)


def ifs_reference(fields, dt=0.05, cutoff=0.5):
    """Reference IFS timestep: physics, analysis, damping, synthesis.

    Uses the same f32 matrices as the compiled path (the transform matrices
    are baked as f32 constants into the HLO), with f64 accumulation.
    """
    fields = np.asarray(fields, dtype=np.float64)
    n = fields.shape[1]
    f, finv = dft_matrices(n, dtype=np.float32)
    damp = spectral_damping(n, cutoff, dtype=np.float32)
    g = physics_reference(fields, dt)
    spec = g @ f.astype(np.float64).T
    spec = spec * damp.astype(np.float64)[None, :]
    return spec @ finv.astype(np.float64).T
