"""AOT lowering: JAX (L2) + Pallas (L1) -> artifacts/*.hlo.txt for Rust.

The interchange format is HLO *text*, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Lowering uses return_tuple=True, so every artifact's output is a tuple and
the Rust side unwraps with `to_tuple()`.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
Emits:
  gs_block_{B}.hlo.txt        B in GS_SIZES
  ifs_step_f{nf}_n{N}.hlo.txt (nf, N) in IFS_SIZES
  model.hlo.txt               alias of the default GS block (Makefile compat)
  manifest.txt                one line per artifact: name shape-signature
"""

import argparse
import os
import shutil

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Paper block sizes are 256/512/1024; the simulated cluster scales the
# whole experiment down 4x, so benches use 64/128/256 (512 kept for the
# e2e example and perf runs).
GS_SIZES = (32, 64, 128, 256, 512)
IFS_SIZES = ((8, 64), (8, 128), (16, 256))
DEFAULT_GS = 256


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_gs(b):
    spec = jax.ShapeDtypeStruct((b, b), jnp.float32)
    vec = jax.ShapeDtypeStruct((b,), jnp.float32)
    return jax.jit(model.gs_step).lower(spec, vec, vec, vec, vec)


def lower_ifs(nf, n):
    fields = jax.ShapeDtypeStruct((nf, n), jnp.float32)
    mat = jax.ShapeDtypeStruct((n, n), jnp.float32)
    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    return jax.jit(model.ifs_step).lower(fields, mat, mat, vec)


def write_ifs_consts(n, out_dir):
    """Binary side file: ft | finvt | damp as little-endian f32."""
    import numpy as np

    ft, finvt, damp = model.ifs_consts(n)
    path = os.path.join(out_dir, f"ifs_consts_n{n}.bin")
    with open(path, "wb") as f:
        f.write(np.asarray(ft, "<f4").tobytes())
        f.write(np.asarray(finvt, "<f4").tobytes())
        f.write(np.asarray(damp, "<f4").tobytes())
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="compat: model.hlo.txt path")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out:  # legacy single-file invocation from old Makefile
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = []
    for b in GS_SIZES:
        name = f"gs_block_{b}"
        text = to_hlo_text(lower_gs(b))
        assert "constant({...})" not in text, f"{name}: elided constants"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name} f32[{b},{b}] x4 f32[{b}] -> (f32[{b},{b}], f32[])")
        print(f"wrote {path} ({len(text)} chars)")

    for nf, n in IFS_SIZES:
        name = f"ifs_step_f{nf}_n{n}"
        text = to_hlo_text(lower_ifs(nf, n))
        assert "constant({...})" not in text, (
            f"{name}: large constants were elided; pass them as arguments"
        )
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        cpath = write_ifs_consts(n, out_dir)
        manifest.append(
            f"{name} f32[{nf},{n}] + consts({os.path.basename(cpath)}) -> (f32[{nf},{n}], f32[])"
        )
        print(f"wrote {path} ({len(text)} chars) + {cpath}")

    shutil.copyfile(
        os.path.join(out_dir, f"gs_block_{DEFAULT_GS}.hlo.txt"),
        os.path.join(out_dir, "model.hlo.txt"),
    )
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {out_dir}/model.hlo.txt and manifest.txt")


if __name__ == "__main__":
    main()
