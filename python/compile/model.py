"""L2: JAX compute graphs the Rust coordinator executes, calling L1 kernels.

Two graphs are lowered AOT (see aot.py):

  * `gs_step`  - one Gauss-Seidel sweep over a (B, B) block given its four
    halo vectors; returns the new block plus the squared-change reduction
    used by the solver's convergence monitor.  XLA fuses the reduction into
    the kernel epilogue.
  * `ifs_step` - one IFSKer timestep over a (nf, n) chunk of fields:
    grid-point physics (Pallas), spectral analysis, high-mode damping,
    synthesis (Pallas tiled matmuls).  The DFT matrices are baked in as
    constants so the Rust side only supplies field data.

Python is build-time only: these functions are never called on the request
path.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import gauss_seidel, spectral
from compile.kernels import ref


def gs_step(u, top, bottom, left, right):
    """One in-block Gauss-Seidel sweep. Returns (new_block, sum((new-u)^2))."""
    new = gauss_seidel.gs_block(u, top, bottom, left, right)
    delta = jnp.sum(jnp.square(new - u))
    return new, delta


def ifs_step(fields, ft, finvt, damp, *, dt=0.05):
    """One IFS timestep: physics -> analysis -> damping -> synthesis.

    The transform matrices are runtime arguments, NOT baked constants:
    `as_hlo_text()` elides large constants (`constant({...})`) and the
    xla_extension 0.5.1 text parser reads the elision as zeros. aot.py
    exports the matrices as binary side files the Rust runtime feeds in.
    """
    g = spectral.physics(fields, dt=dt)
    spec = spectral.matmul(g, ft)
    spec = spec * damp[None, :]
    out = spectral.matmul(spec, finvt)
    norm = jnp.sum(jnp.square(out))
    return out, norm


def ifs_consts(n, cutoff=0.5):
    """The (ft, finvt, damp) arrays `ifs_step` expects for width n."""
    f, finv = ref.dft_matrices(n)
    damp = ref.spectral_damping(n, cutoff)
    ft = np.ascontiguousarray(f.T)
    finvt = np.ascontiguousarray(finv.T)
    return ft, finvt, damp


def make_ifs_step(n, dt=0.05, cutoff=0.5):
    """Python-side convenience: `ifs_step` with bound transform matrices."""
    ft, finvt, damp = (jnp.asarray(x) for x in ifs_consts(n, cutoff))

    def step(fields):
        return ifs_step(fields, ft, finvt, damp, dt=dt)

    return step
