//! Offline API shim for the `xla` surface `rust/src/runtime/pjrt.rs`
//! uses: `PjRtClient`, `PjRtLoadedExecutable`, `PjRtBuffer`,
//! `HloModuleProto`, `XlaComputation`, `Literal`. It type-checks the
//! real PJRT bridge in CI (`cargo check --features pjrt`) without any
//! network access; at runtime every entry point fails with a clear
//! "no real XLA runtime" error, which the apps and tests treat exactly
//! like missing artifacts. Replace with the real vendored `xla` crate
//! when the offline registry lands (ROADMAP "Vendor the PJRT deps").

/// Error type of the shim; formatted with `{:?}` by the bridge.
#[derive(Debug)]
pub struct XlaError(pub String);

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: vendored xla API shim has no real XLA/PJRT runtime (see vendor/README.md)"
    ))
}

/// Host literal: flat f32 data plus dims (the subset the bridge moves).
#[derive(Clone, Default)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

/// Element types `Literal::to_vec` can yield in the shim.
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

impl Literal {
    /// Rank-1 literal over host data.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reshape without moving data.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let want: i64 = dims.iter().product();
        if want != self.data.len() as i64 {
            return Err(XlaError(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Tuple destructuring — shim literals are never tuples.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Host copy-out.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Dims accessor (kept for API parity).
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (never successfully constructed by the shim).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({path})")))
    }
}

/// Computation wrapper.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle returned by `execute`.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with host inputs; `L` is the input literal type (the
    /// bridge passes `xla::Literal`).
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
pub struct PjRtClient(());

impl PjRtClient {
    /// CPU client — unavailable in the shim (callers surface the error
    /// exactly as they surface missing artifacts).
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn runtime_entry_points_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
