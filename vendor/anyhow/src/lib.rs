//! Offline API shim for the `anyhow` surface `rust/src/runtime/pjrt.rs`
//! uses: `Error`, `Result`, the `Context` trait, and the `anyhow!` /
//! `ensure!` macros. Behaviourally it is a plain string-error crate —
//! enough for CI to type-check the PJRT bridge without network access.
//! Replace with the real vendored `anyhow` when the offline registry
//! lands (ROADMAP "Vendor the PJRT deps").

use std::fmt;

/// String-backed error (the shim of `anyhow::Error`).
pub struct Error(String);

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// The shim of `anyhow::Context`: attach context to failures.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Debug> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error(format!("{c}: {e:?}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e:?}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// The shim of `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// The shim of `anyhow::ensure!`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_paths() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(format!("{e}").starts_with("outer: "));
        let o: Option<u32> = None;
        assert!(o.with_context(|| "missing").is_err());
    }

    #[test]
    fn macros() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "bad {}", 7);
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(format!("{}", f(false).unwrap_err()), "bad 7");
    }
}
